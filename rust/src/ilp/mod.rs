//! 0-1 Integer Linear Programming (paper §III-C).
//!
//! The paper formulates strategy selection as an ILP and solves it with
//! PuLP; this is the in-tree equivalent: a problem builder with named
//! binary variables and linear constraints, solved exactly by branch &
//! bound ([`bb`]) over LP relaxations computed with a two-phase dense
//! simplex ([`simplex`]). Problems at HAP's scale (≤ a few hundred
//! binaries) solve in well under a millisecond.

pub mod bb;
pub mod reference;
pub mod simplex;

use std::collections::HashMap;
use std::fmt;

/// Variable handle.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Var(pub usize);

/// Constraint comparison sense.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Sense {
    Le,
    Ge,
    Eq,
}

/// A linear expression `Σ coeff_i · x_i`.
#[derive(Debug, Clone, Default)]
pub struct LinExpr {
    /// Sparse terms var-index → coefficient.
    pub terms: HashMap<usize, f64>,
}

impl LinExpr {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn term(mut self, var: Var, coeff: f64) -> Self {
        *self.terms.entry(var.0).or_insert(0.0) += coeff;
        self
    }

    pub fn add_term(&mut self, var: Var, coeff: f64) {
        *self.terms.entry(var.0).or_insert(0.0) += coeff;
    }

    /// Sum of unit terms over vars.
    pub fn sum(vars: &[Var]) -> Self {
        let mut e = Self::new();
        for &v in vars {
            e.add_term(v, 1.0);
        }
        e
    }

    pub fn eval(&self, x: &[f64]) -> f64 {
        self.terms.iter().map(|(&i, &c)| c * x[i]).sum()
    }
}

/// A linear constraint `expr (≤|=|≥) rhs`.
#[derive(Debug, Clone)]
pub struct Constraint {
    pub expr: LinExpr,
    pub sense: Sense,
    pub rhs: f64,
    pub name: String,
}

impl Constraint {
    pub fn satisfied(&self, x: &[f64], tol: f64) -> bool {
        let v = self.expr.eval(x);
        match self.sense {
            Sense::Le => v <= self.rhs + tol,
            Sense::Ge => v >= self.rhs - tol,
            Sense::Eq => (v - self.rhs).abs() <= tol,
        }
    }
}

/// A 0-1 ILP minimization problem.
#[derive(Debug, Clone, Default)]
pub struct Problem {
    pub num_vars: usize,
    pub var_names: Vec<String>,
    pub objective: LinExpr,
    pub constraints: Vec<Constraint>,
}

impl Problem {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a binary variable.
    pub fn binary(&mut self, name: &str) -> Var {
        let v = Var(self.num_vars);
        self.num_vars += 1;
        self.var_names.push(name.to_string());
        v
    }

    /// Add `n` binary variables with an indexed name prefix.
    pub fn binaries(&mut self, prefix: &str, n: usize) -> Vec<Var> {
        (0..n).map(|i| self.binary(&format!("{prefix}[{i}]"))).collect()
    }

    /// Set a coefficient in the (minimization) objective.
    pub fn set_objective_term(&mut self, var: Var, coeff: f64) {
        self.objective.add_term(var, coeff);
    }

    pub fn constrain(&mut self, name: &str, expr: LinExpr, sense: Sense, rhs: f64) {
        self.constraints.push(Constraint { expr, sense, rhs, name: name.to_string() });
    }

    /// `Σ vars = 1` (one-hot selection).
    pub fn exactly_one(&mut self, name: &str, vars: &[Var]) {
        self.constrain(name, LinExpr::sum(vars), Sense::Eq, 1.0);
    }

    /// Linearized conjunction: `y = a ∧ b` for binaries.
    pub fn and_var(&mut self, name: &str, a: Var, b: Var) -> Var {
        let y = self.binary(name);
        self.constrain(
            &format!("{name}.ge"),
            LinExpr::new().term(y, 1.0).term(a, -1.0).term(b, -1.0),
            Sense::Ge,
            -1.0,
        );
        self.constrain(&format!("{name}.le_a"), LinExpr::new().term(y, 1.0).term(a, -1.0), Sense::Le, 0.0);
        self.constrain(&format!("{name}.le_b"), LinExpr::new().term(y, 1.0).term(b, -1.0), Sense::Le, 0.0);
        y
    }

    /// Objective value at an assignment.
    pub fn objective_value(&self, x: &[f64]) -> f64 {
        self.objective.eval(x)
    }

    /// All constraints satisfied at tolerance?
    pub fn feasible(&self, x: &[f64], tol: f64) -> bool {
        self.constraints.iter().all(|c| c.satisfied(x, tol))
    }
}

/// Solver outcome.
#[derive(Debug, Clone)]
pub enum Outcome {
    /// Optimal assignment (0/1 values) and objective.
    Optimal { x: Vec<f64>, objective: f64, nodes_explored: usize },
    Infeasible,
}

impl Outcome {
    pub fn optimal(&self) -> Option<(&[f64], f64)> {
        match self {
            Outcome::Optimal { x, objective, .. } => Some((x, *objective)),
            Outcome::Infeasible => None,
        }
    }
}

impl fmt::Display for Outcome {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Outcome::Optimal { objective, nodes_explored, .. } => {
                write!(f, "optimal obj={objective:.6e} ({nodes_explored} nodes)")
            }
            Outcome::Infeasible => write!(f, "infeasible"),
        }
    }
}

/// Solve a 0-1 ILP exactly (branch & bound with LP-relaxation bounds).
pub fn solve(problem: &Problem) -> Outcome {
    bb::branch_and_bound(problem)
}

/// Solve with a warm-start incumbent: `warm` is a known-feasible 0/1
/// assignment (e.g. the planner's brute-force-over-tables optimum)
/// seeding branch & bound's upper bound so pruning starts at node one.
/// Exact like [`solve`]; never explores more nodes than a cold start.
pub fn solve_warm(problem: &Problem, warm: &[f64]) -> Outcome {
    bb::branch_and_bound_warm(problem, Some(warm))
}

/// Solve with the pre-optimization reference solver (perf baselines,
/// cross-checks). Same optima, slower.
pub fn solve_reference(problem: &Problem) -> Outcome {
    reference::solve(problem)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn one_hot_picks_min_cost() {
        let mut p = Problem::new();
        let xs = p.binaries("x", 4);
        for (i, &v) in xs.iter().enumerate() {
            p.set_objective_term(v, [5.0, 2.0, 7.0, 3.0][i]);
        }
        p.exactly_one("pick", &xs);
        let out = solve(&p);
        let (x, obj) = out.optimal().expect("feasible");
        assert_eq!(obj, 2.0);
        assert_eq!(x[1], 1.0);
    }

    #[test]
    fn and_var_linearization() {
        // min -(a ∧ b) with a forced on and b forced off → y must be 0.
        let mut p = Problem::new();
        let a = p.binary("a");
        let b = p.binary("b");
        let y = p.and_var("y", a, b);
        p.set_objective_term(y, -1.0);
        p.constrain("a_on", LinExpr::new().term(a, 1.0), Sense::Eq, 1.0);
        p.constrain("b_off", LinExpr::new().term(b, 1.0), Sense::Eq, 0.0);
        let out = solve(&p);
        let (x, obj) = out.optimal().unwrap();
        assert_eq!(obj, 0.0);
        assert_eq!(x[y.0], 0.0);

        // Now allow both on: y should be 1 (objective rewards it).
        let mut p = Problem::new();
        let a = p.binary("a");
        let b = p.binary("b");
        let y = p.and_var("y", a, b);
        p.set_objective_term(y, -1.0);
        let out = solve(&p);
        let (x, _) = out.optimal().unwrap();
        assert_eq!(x[y.0], 1.0);
        assert_eq!(x[a.0], 1.0);
        assert_eq!(x[b.0], 1.0);
    }

    #[test]
    fn infeasible_detected() {
        let mut p = Problem::new();
        let xs = p.binaries("x", 2);
        p.exactly_one("one", &xs);
        p.constrain("none", LinExpr::sum(&xs), Sense::Eq, 0.0);
        assert!(matches!(solve(&p), Outcome::Infeasible));
    }

    #[test]
    fn knapsack_small() {
        // max 6x0+10x1+12x2 s.t. x0+2x1+3x2 <= 4  → min form.
        let mut p = Problem::new();
        let xs = p.binaries("x", 3);
        for (i, &v) in xs.iter().enumerate() {
            p.set_objective_term(v, [-6.0, -10.0, -12.0][i]);
        }
        let mut cap = LinExpr::new();
        for (i, &v) in xs.iter().enumerate() {
            cap.add_term(v, [1.0, 2.0, 3.0][i]);
        }
        p.constrain("cap", cap, Sense::Le, 4.0);
        let out = solve(&p);
        let (x, obj) = out.optimal().unwrap();
        // Best: x1 + x2? weight 5 > 4. x0+x2 weight 4 value 18. ✓
        assert_eq!(obj, -18.0);
        assert_eq!((x[0], x[1], x[2]), (1.0, 0.0, 1.0));
    }
}
