//! The serving `Engine`: a long-lived session facade over the grid
//! executor with **continuous batching** and in-flight hybrid plan
//! switches — the public serving API.
//!
//! The previous surface (`serve_workload`/`serve_on` free functions)
//! gang-scheduled a fixed batch through prefill and decoded until the
//! *slowest* member finished, so short requests convoyed behind long
//! ones and the adapt loop only saw traffic at coarse batch
//! boundaries. The `Engine` runs an Orca-style iteration scheduler
//! instead:
//!
//! 1. **retire** — finished sequences leave the live batch
//!    ([`crate::model::ModelExecutor::release_slot`]), freeing their KV
//!    slot mid-decode;
//! 2. **advance + admit** — slots mid-way through a **chunked
//!    prefill** advance by one chunk, and queued requests claim freed
//!    slots and run their first chunk
//!    ([`crate::model::ModelExecutor::prefill_slot`]) while their
//!    peers keep decoding;
//! 3. **decode** — one step for the fully-prefilled running set at
//!    per-slot positions
//!    ([`crate::model::ModelExecutor::decode_slots`]).
//!
//! One [`Engine::step`] call runs one such iteration; [`Engine::submit`]
//! enqueues work (with drain-based backpressure instead of the old
//! hard `bail!` on a full queue), [`Engine::poll`]/[`Engine::drain`]
//! deliver tokens, and [`Engine::shutdown`] returns the familiar
//! [`ServeReport`].
//!
//! **Chunked prefill** ([`ServeConfig::prefill_chunk`]). With a
//! non-zero chunk, a joiner's padded prompt is prefilled at most
//! `prefill_chunk` tokens per iteration through the executor's
//! *resumable* `prefill_slot` (ranged attention writing KV at the
//! slot's cursor), so a long-prompt joiner no longer stalls its peers'
//! decode step for a whole prompt — peer decode iterations interleave
//! between chunks. A slot in the *Prefilling* phase takes no decode
//! steps and emits its first token only when the final chunk's logits
//! land (TTFT is measured there); causal attention makes the chunked
//! computation bit-identical to a one-shot prefill, so per-request
//! tokens still match the gang scheduler exactly. `0` (the default)
//! keeps the one-iteration-per-prompt behavior.
//!
//! **Plan switches at iteration granularity.** With an adaptive config,
//! the adapt loop ([`crate::adapt::AdaptLoop`] via [`AdaptState`]) is
//! consulted at every admission boundary instead of once per gang
//! batch. A switch that keeps the attention layout (expert resharding —
//! the common HAP transition) applies immediately: per-slot KV caches
//! are untouched, so in-flight decodes continue under the new expert
//! layout while the executor's measured reshard moves the expert
//! weights. A switch that changes the attention layout invalidates the
//! KV sharding, so the engine stops admitting, drains in-flight decodes
//! to the safe point (running set empty), re-begins the session under
//! the new layout, and resumes admission — or applies on the spot when
//! the running set is already empty at decision time.
//!
//! **Measured feedback at iteration granularity.** The session
//! aggregates each iteration's wall time (prefill chunks + decode
//! steps) and the tokens it generated into a per-plan dwell
//! accumulator; at every admission-boundary consult the accumulated
//! [`MeasuredLatency`] is handed to the adapt loop, which normalizes
//! it — and the planner's prediction for the same traffic key — to
//! **seconds per generated token** before folding the ratio into the
//! controller's mispredict EWMA. Gang mode feeds whole-batch
//! observations through the same normalized API, so both schedulers
//! demote consistently mispredicted plans with commensurable units and
//! the streaming path's controller is no longer blind
//! (`measured: None`) where adaptation actually happens.
//!
//! **Equivalence.** Every kernel in the host stack is row-independent,
//! so a sequence's tokens depend only on its own (padded) prompt and
//! the weights — never on which peers share the batch. Streaming
//! scheduling therefore produces per-request token sequences
//! bit-identical to the gang path (`rust/tests/engine_api.rs`).
//!
//! **Fault recovery (streaming mode).** With a
//! [`crate::model::FaultPlan`] installed on the executor, device
//! failures surface as structured `fault[kind]` errors from the step's
//! compute ops, and [`Session::step`] runs a detection → retry →
//! degrade → requeue state machine over them:
//!
//! - **detection** — any step error is classified by
//!   [`crate::model::fault::classify`]; a classified fault increments
//!   `Metrics::faults_detected`, an unclassified error latches the
//!   engine into [`EngineState::Failed`] (no corrupt re-entry; see
//!   the de-panicked [`EngineError`] invariants).
//! - **retry** — retryable faults (`Stall`, `Transient`) are retried
//!   with bounded deterministic backoff: the engine burns `1, 2, 4,
//!   8, 16` *scheduler iterations* (never wall-clock time) between
//!   attempts, up to [`MAX_FAULT_RETRIES`]. Every compute op left the
//!   per-slot state untouched on error (cursors restored, positions
//!   unadvanced), so a successful retry re-runs the exact same op and
//!   the token streams stay bit-identical — transient faults are
//!   absorbed with **zero requeues**.
//! - **degrade** — a `Crash` (or an exhausted retry budget, which
//!   promotes the faulting device to lost) triggers degraded
//!   re-planning: the surviving device count rounds down to a power of
//!   two, the planner's node shrinks to it (adaptive engines re-plan
//!   through the same [`AdaptState`]; the plan cache's platform
//!   fingerprint changes, so stale full-grid plans are never served),
//!   and fixed-plan engines fall back to `TP(n_survivors)`.
//! - **requeue** — every in-flight request on the dead grid returns to
//!   the head of the backlog and replays from its prompt on the
//!   degraded grid (`Metrics::requests_recovered`). Host kernels are
//!   deterministic and row-independent, so recovered requests produce
//!   tokens bit-identical to the same workload run unfaulted on a
//!   grid of the degraded size. When no grid survives, every request
//!   drains as [`RequestStatus::Failed`] with a structured reason
//!   (`Metrics::requests_failed`) and the engine latches `Failed`.
//!
//! Gang mode has no mid-batch recovery point (a batch's generated
//! tokens live on the `gang_step` stack), so any gang step error
//! latches the engine.
//!
//! The gang scheduler is retained behind [`Scheduling::Gang`] — it is
//! what the deprecated `serve_workload`/`serve_on` wrappers run, the
//! only mode the fixed-shape PJRT artifacts support, and the baseline
//! `hap serve --engine gang` compares against.

use super::batcher::Batcher;
use super::metrics::Metrics;
use super::router::Router;
use super::server::{AdaptiveServing, ServeConfig, ServeReport};
use super::{Request, Response};
use crate::adapt::window::TrafficSample;
use crate::adapt::{AdaptLoop, MeasuredLatency, PlanCache, SwitchDecision};
use crate::config::hardware::NodeConfig;
use crate::model::fault::{classify, faulted_device};
use crate::model::{
    EngineMode, ExecStats, FaultPlan, KvLayout, ModelExecutor, ShardPlan, WeightStore,
};
use crate::obs::{EventKind, ModuleTimes, Recorder, TraceEvent};
use crate::planner::{HapPlanner, PLANNER_SEED};
use crate::runtime::literal::argmax_rows;
use crate::runtime::{PjrtRuntime, TinyModelMeta};
use crate::Result;
use std::time::Instant;

/// Requests are identified by their caller-assigned `Request::id`
/// (unique per engine; `poll` looks them up by it).
pub type RequestId = u64;

/// How the engine schedules work across the batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheduling {
    /// Pack a batch, prefill once, decode until the slowest member
    /// finishes (the legacy run-to-completion path; required by the
    /// fixed-shape PJRT artifacts).
    Gang,
    /// Continuous batching: retire/admit/decode every iteration with
    /// per-slot KV positions (host backend).
    Streaming,
}

impl Scheduling {
    pub fn parse(s: &str) -> Option<Scheduling> {
        match s {
            "gang" => Some(Scheduling::Gang),
            "streaming" => Some(Scheduling::Streaming),
            _ => None,
        }
    }
}

/// What one [`Engine::step`] did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StepOutcome {
    /// Requests admitted (chunked-prefilled) this iteration.
    pub admitted: usize,
    /// Requests retired (responses now pollable).
    pub retired: usize,
    /// Slot decode steps taken: live slots summed over the decode
    /// iterations this step ran — one iteration in streaming mode, the
    /// whole batch's convoy in gang mode — so both schedulers report
    /// the same quantity.
    pub decoded: usize,
    /// Live slots after the iteration.
    pub running: usize,
    /// Requests still queued after the iteration.
    pub queued: usize,
    /// A plan switch was applied (reshard or session restart).
    pub switched: bool,
}

impl StepOutcome {
    /// True when the step found nothing to do.
    pub fn idle(&self) -> bool {
        self.admitted == 0 && self.retired == 0 && self.decoded == 0 && self.running == 0
    }
}

/// Non-blocking per-request progress (see [`Engine::poll`]).
#[derive(Debug, Clone)]
pub enum RequestStatus {
    /// Waiting in the admission queue.
    Queued,
    /// In a batch slot; `tokens` generated so far.
    Running { tokens: Vec<i32> },
    /// Complete; the full response.
    Finished(Response),
    /// Removed by [`Engine::cancel`] before completion.
    Cancelled,
    /// Drained by the engine without completing — e.g. no grid
    /// survived a device crash. The reason is the structured cause.
    Failed { reason: String },
    /// Never submitted (or submitted to a different engine).
    Unknown,
}

/// Coarse engine health, derived from the recovery state machine (see
/// the module docs and [`Engine::state`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineState {
    /// Serving on the full device grid.
    Healthy,
    /// A confirmed device loss degraded the grid: serving continues on
    /// `devices` survivors (largest power of two that fits).
    Degraded { devices: usize },
    /// A fatal error latched; every further `step()` returns the same
    /// structured error instead of re-entering corrupt state.
    Failed,
}

/// Bounded retry budget for retryable faults (`Stall`, `Transient`)
/// before the faulting device is promoted to lost and the engine
/// degrades. Backoff between attempts is `1, 2, 4, 8, 16` scheduler
/// iterations — deterministic, never wall-clock.
pub const MAX_FAULT_RETRIES: usize = 5;

/// Structured scheduler-invariant violations — the de-panicked
/// `expect()` cluster of the streaming hot path. A bug (or a fault
/// interleaving the scheduler into a state it never expected) surfaces
/// as a recoverable `Err` from `step()` instead of a poisoned process.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineError {
    /// A slot operation ran without an active session.
    NoSession { at: &'static str },
    /// `slots[idx]` was unexpectedly empty.
    EmptySlot { slot: usize, at: &'static str },
    /// The slot was expected to be mid-prefill and wasn't.
    NotPrefilling { slot: usize },
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::NoSession { at } => {
                write!(f, "engine invariant: no active session ({at})")
            }
            EngineError::EmptySlot { slot, at } => {
                write!(f, "engine invariant: slot {slot} unexpectedly empty ({at})")
            }
            EngineError::NotPrefilling { slot } => {
                write!(f, "engine invariant: slot {slot} is not prefilling")
            }
        }
    }
}

impl std::error::Error for EngineError {}

/// Typed admission failure for the non-blocking [`Engine::try_submit`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The admission queue is full. `retry_after_iters` is a
    /// deterministic hint derived from the running set: the shortest
    /// remaining decode budget among decoding slots (a slot frees no
    /// sooner than that many iterations), minimum 1.
    QueueFull { retry_after_iters: usize },
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { retry_after_iters } => write!(
                f,
                "admission queue full; retry after ~{retry_after_iters} scheduler iterations"
            ),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Per-run state of the adaptation loop: the shared [`AdaptLoop`] (the
/// exact implementation the replay acceptance tests validate) plus the
/// platform's latency model, resolved once so the per-consult path
/// never touches the global model-cache lock.
pub(crate) struct AdaptState {
    pub(crate) control: AdaptLoop,
    latency: std::sync::Arc<crate::sim::LatencyModel>,
}

impl AdaptState {
    pub(crate) fn new(cfg: &AdaptiveServing) -> AdaptState {
        let mut control = AdaptLoop::new(cfg.controller.clone(), cfg.window_capacity);
        if let Some(path) = &cfg.plan_cache {
            match PlanCache::load(path, &cfg.model, &cfg.node) {
                Ok(cache) => control.cache = cache,
                Err(e) => eprintln!("plan cache {}: {e:#} (starting cold)", path.display()),
            }
        }
        AdaptState {
            control,
            latency: crate::sim::LatencyModel::cached(&cfg.node.gpu, PLANNER_SEED),
        }
    }

    /// Observe one admission boundary's traffic — plus the measured
    /// execution since the previous boundary (one whole batch in gang
    /// mode, the dwell window of iterations in streaming mode), which
    /// closes the loop on mispredicted plans — and return the
    /// (prefill, decode) plans the controller lands on, with its
    /// decision so the caller can count weight-moving switches. The
    /// grid engine executes whatever the planner picked — hybrids
    /// included.
    pub(crate) fn select(
        &mut self,
        cfg: &AdaptiveServing,
        samples: &[TrafficSample],
        measured: Option<MeasuredLatency>,
    ) -> Result<(ShardPlan, ShardPlan, SwitchDecision)> {
        let planner = HapPlanner::with_latency(&cfg.model, &cfg.node, self.latency.clone());
        let (plan, decision) =
            self.control.step(&planner, samples.iter().copied(), None, measured)?;
        Ok((
            ShardPlan::new(plan.attn, plan.expert_prefill),
            ShardPlan::new(plan.attn, plan.expert_decode),
            decision,
        ))
    }
}

/// A request occupying one batch slot.
struct Slot {
    req: Request,
    tokens: Vec<i32>,
    last: i32,
    remaining: usize,
    ttft: f64,
    /// Chunked-prefill state: the padded prompt row and the chunk
    /// cursor (tokens prefilled so far). `Some` while the slot is in
    /// the *Prefilling* phase — it takes no decode steps, and its
    /// first token (and TTFT) lands only when the final chunk's logits
    /// do. `None` once decoding. Under paged KV the cursor starts at
    /// the trie-matched prefix length instead of 0 (shared prefill
    /// work is skipped).
    prefill: Option<(Vec<i32>, usize)>,
    /// Paged KV: blocks reserved for this request at admission
    /// (`ceil((prompt + budget) / block_size)`); `0` under the padded
    /// layout. Admission backpressures when the sum over occupied
    /// slots would exceed the pool.
    kv_blocks: usize,
}

impl Slot {
    /// Whether this slot takes decode steps (prefill fully landed).
    fn decoding(&self) -> bool {
        self.prefill.is_none()
    }
}

/// The scheduler core, separated from executor ownership so the compat
/// wrappers ([`serve_with`]) can drive a caller-owned executor while
/// [`Engine`] owns its own.
struct Session {
    config: ServeConfig,
    scheduling: Scheduling,
    meta: TinyModelMeta,
    batcher: Batcher,
    router: Router,
    /// Joiners already taken from the router when an attention-layout
    /// switch was decided: they wait here (in admission order) while
    /// the running set drains, and are admitted first under the new
    /// session.
    backlog: Vec<Request>,
    slots: Vec<Option<Slot>>,
    /// Every completed response, in retirement order (the report).
    responses: Vec<Response>,
    /// Delivery watermark: `responses[..delivered]` have been handed
    /// out by `drain`; the tail is pending delivery. An index instead
    /// of a second Vec so tokens are stored once and the retire path
    /// never deep-clones.
    delivered: usize,
    metrics: Metrics,
    adapt: Option<AdaptState>,
    /// Gang mode: previous batch's measured execution for the adapt
    /// loop (wall seconds + tokens generated).
    last_measured: Option<MeasuredLatency>,
    /// Streaming: wall seconds of model execution (prefill chunks +
    /// decode steps) accumulated under the active plan since the last
    /// adapt consult — the per-plan dwell accumulator...
    dwell_seconds: f64,
    /// ...and the tokens generated in that window. Together they are
    /// the `MeasuredLatency` handed to the adapt loop at the next
    /// admission boundary (then reset), closing the measured-latency
    /// feedback at iteration granularity.
    dwell_tokens: usize,
    /// Set by [`Self::request_plans`]: the session's plan was forced
    /// out from under the controller, so the next consult's dwell
    /// window ran under a plan the controller does not consider
    /// active — withhold it from the mispredict EWMA (and drop it)
    /// instead of attributing it to the wrong plan.
    suppress_measured: bool,
    /// Streaming: the session's resident (prefill, decode) plans.
    active: Option<(ShardPlan, ShardPlan)>,
    /// Streaming: an attention-layout switch waiting for the running
    /// set to drain.
    pending: Option<(ShardPlan, ShardPlan)>,
    prefill_time: f64,
    decode_time: f64,
    stats0: ExecStats,
    run_start: Instant,
    /// Fatal-error latch: once set, every further `step()` returns the
    /// same structured error instead of re-entering corrupt state
    /// ([`EngineState::Failed`]).
    failed: Option<String>,
    /// Consecutive failed step attempts on the current fault (reset by
    /// any successful step).
    retry_attempts: usize,
    /// Scheduler iterations still to burn before the next retry —
    /// deterministic, iteration-counted backoff (never wall-clock).
    backoff_iters: usize,
    /// Device count the session degraded to after a confirmed device
    /// loss (`None` = full grid). Overrides the fixed fallback plans
    /// with `TP(n)` on the survivors.
    degraded_n: Option<usize>,
    /// Requests recovered by degraded re-planning: requeued and
    /// replayed from their prompt, in recovery order.
    recovered_ids: Vec<RequestId>,
    /// Requests removed by [`Engine::cancel`].
    cancelled_ids: Vec<RequestId>,
    /// Requests drained without completing, with structured reasons
    /// (e.g. no grid survived) — reported as `RequestStatus::Failed`.
    failed_requests: Vec<(RequestId, String)>,
    /// Deterministic trace recorder (disabled unless installed via
    /// [`EngineBuilder::recorder`] or [`serve_with_recorder`]). Events
    /// are keyed on the scheduler-iteration counter below plus the
    /// executor fault clock; wall time rides along as payload only.
    recorder: Recorder,
    /// Scheduler iterations run so far — the trace's primary
    /// deterministic ordering key (backoff burns count too).
    iterations: u64,
    /// Paged KV: pool alloc/free counter watermarks from the previous
    /// iteration, so each step records only the delta as
    /// `BlockAlloc`/`BlockFree` events. Reset to 0 when a session
    /// restart rebuilds the pool (counters restart below the
    /// watermark).
    kv_allocs_seen: u64,
    kv_frees_seen: u64,
    /// Streaming, budget-driven chunk sizing
    /// ([`ServeConfig::prefill_budget_ms`]): EWMA of the measured
    /// prefill rate in tokens/second, updated after every successful
    /// chunk. `None` until the first measurement (static sizing until
    /// then). Wall-clock-derived — sizes chunks, never tokens, so
    /// per-request tokens stay bit-identical regardless of its value.
    prefill_rate: Option<f64>,
}

impl Session {
    fn new(exec: &ModelExecutor, config: ServeConfig, scheduling: Scheduling) -> Session {
        let meta = exec.meta().clone();
        let batcher = Batcher::new(meta.batch, meta.prefill_len, meta.max_len - meta.prefill_len);
        let router = Router::new(config.queue_capacity, config.policy);
        let adapt = config.adaptive.as_ref().map(AdaptState::new);
        Session {
            slots: (0..meta.batch).map(|_| None).collect(),
            backlog: Vec::new(),
            responses: Vec::new(),
            delivered: 0,
            metrics: Metrics::new(),
            adapt,
            last_measured: None,
            dwell_seconds: 0.0,
            dwell_tokens: 0,
            suppress_measured: false,
            active: None,
            pending: None,
            prefill_time: 0.0,
            decode_time: 0.0,
            stats0: exec.stats(),
            run_start: Instant::now(),
            failed: None,
            retry_attempts: 0,
            backoff_iters: 0,
            degraded_n: None,
            recovered_ids: Vec::new(),
            cancelled_ids: Vec::new(),
            failed_requests: Vec::new(),
            recorder: Recorder::disabled(),
            iterations: 0,
            kv_allocs_seen: 0,
            kv_frees_seen: 0,
            prefill_rate: None,
            config,
            scheduling,
            meta,
            batcher,
            router,
        }
    }

    /// Enqueue a request. A full queue backpressures by running
    /// scheduler iterations until a slot frees (a full queue is never
    /// empty, so every iteration makes progress) — the old API's hard
    /// `bail!` on overflow is gone.
    fn submit(&mut self, exec: &mut ModelExecutor, req: Request) -> Result<RequestId> {
        if self.router.capacity == 0 {
            anyhow::bail!("queue capacity is 0 — no request can ever be admitted");
        }
        let id = req.id;
        let mut req = req;
        loop {
            // Wait for queue room BEFORE attempting admission: engine
            // backpressure is a drain, not a rejection, so the waiting
            // iterations leave the router's `rejected` counter alone
            // (it keeps counting only true rejections seen by direct
            // router users).
            if self.router.pending() < self.router.capacity {
                match self.router.try_submit(req) {
                    None => return Ok(id),
                    Some(back) => req = back,
                }
            }
            self.step(exec)?;
        }
    }

    /// One scheduler iteration, wrapped by the fault-recovery state
    /// machine (module docs: detection → retry → degrade → requeue).
    /// A latched engine returns its structured failure; a backoff
    /// iteration makes no executor call (burning one deterministic
    /// wait unit); otherwise the scheduling-mode step runs and its
    /// error, if any, is classified and handled.
    fn step(&mut self, exec: &mut ModelExecutor) -> Result<StepOutcome> {
        if let Some(reason) = &self.failed {
            anyhow::bail!("engine failed: {reason}");
        }
        self.iterations += 1;
        if self.backoff_iters > 0 {
            self.backoff_iters -= 1;
            return Ok(self.idle_outcome());
        }
        let result = match self.scheduling {
            Scheduling::Gang => self.gang_step(exec),
            Scheduling::Streaming => self.stream_step(exec),
        };
        match result {
            Ok(out) => {
                self.retry_attempts = 0;
                Ok(out)
            }
            Err(e) => self.handle_step_error(exec, e),
        }
    }

    /// A no-op outcome that still reports live/queued counts, so
    /// drivers looping on [`Self::idle`] keep making progress through
    /// backoff iterations.
    fn idle_outcome(&self) -> StepOutcome {
        StepOutcome {
            running: self.slots.iter().filter(|s| s.is_some()).count(),
            queued: self.router.pending() + self.backlog.len(),
            ..StepOutcome::default()
        }
    }

    /// The executor fault clock — the secondary deterministic ordering
    /// key traced alongside the scheduler iteration (0 when no fault
    /// plan is installed).
    fn fault_clock(exec: &ModelExecutor) -> u64 {
        exec.fault_plan().map(|f| f.iteration()).unwrap_or(0)
    }

    /// Record one trace event at the current (iteration, fault-clock)
    /// coordinates. A no-op when the recorder is disabled — callers
    /// with expensive payloads (module-time snapshots) should gate on
    /// `self.recorder.is_enabled()` themselves.
    fn record(&mut self, exec: &ModelExecutor, kind: EventKind) {
        self.recorder.record(self.iterations, Self::fault_clock(exec), kind);
    }

    /// Human label for a (prefill, decode) plan pair in `Switch`
    /// events.
    fn plans_label(plans: &(ShardPlan, ShardPlan)) -> String {
        if plans.0 == plans.1 {
            plans.0.label()
        } else {
            format!("prefill[{}] decode[{}]", plans.0.label(), plans.1.label())
        }
    }

    /// Run a plan-applying executor call and trace the reshard work it
    /// did (weight-move count and seconds, from the executor stats
    /// delta) as a `Reshard` event.
    fn trace_reshard<F>(&mut self, exec: &mut ModelExecutor, apply: F) -> Result<()>
    where
        F: FnOnce(&mut ModelExecutor) -> Result<()>,
    {
        let s0 = self.recorder.is_enabled().then(|| exec.stats());
        apply(exec)?;
        if let Some(s0) = s0 {
            let s1 = exec.stats();
            if s1.reshards > s0.reshards {
                self.record(
                    exec,
                    EventKind::Reshard {
                        count: s1.reshards - s0.reshards,
                        secs: s1.reshard_seconds - s0.reshard_seconds,
                    },
                );
            }
        }
        Ok(())
    }

    /// Classify a step error and dispatch the recovery state machine.
    /// Returns `Ok` when the engine absorbed the fault (retry scheduled
    /// or grid degraded) and `Err` when it latched.
    fn handle_step_error(
        &mut self,
        exec: &mut ModelExecutor,
        e: anyhow::Error,
    ) -> Result<StepOutcome> {
        if self.scheduling != Scheduling::Streaming {
            // Gang mode has no mid-batch recovery point (the batch's
            // generated tokens live on the gang_step stack): latch.
            self.failed = Some(format!("{e:#}"));
            return Err(e);
        }
        match classify(&e) {
            Some(kind) if kind.retryable() && self.retry_attempts < MAX_FAULT_RETRIES => {
                let device = faulted_device(&e).unwrap_or(0);
                if self.retry_attempts == 0 {
                    self.metrics.faults_detected += 1;
                    self.record(
                        exec,
                        EventKind::FaultDetected {
                            device,
                            kind: format!("{kind:?}"),
                            attempt: 1,
                        },
                    );
                }
                self.retry_attempts += 1;
                self.metrics.fault_retries += 1;
                // 1, 2, 4, 8, 16 scheduler iterations — deterministic,
                // iteration-counted, never wall-clock. The fault clock
                // only advances on real executor ops, so a stall's
                // window is consumed by the retries themselves; the
                // backoff just spaces them out.
                self.backoff_iters = 1usize << (self.retry_attempts - 1).min(4);
                self.record(
                    exec,
                    EventKind::Retry {
                        attempt: self.retry_attempts,
                        backoff_iters: self.backoff_iters,
                    },
                );
                Ok(self.idle_outcome())
            }
            Some(kind) => {
                // A crash — or a retryable fault whose budget is
                // exhausted, which promotes the device to lost.
                if self.retry_attempts == 0 || kind == crate::model::FaultKind::Crash {
                    self.metrics.faults_detected += 1;
                    let device = faulted_device(&e)
                        .or_else(|| exec.crashed_devices().first().copied())
                        .unwrap_or(0);
                    self.record(
                        exec,
                        EventKind::FaultDetected {
                            device,
                            kind: format!("{kind:?}"),
                            attempt: self.retry_attempts + 1,
                        },
                    );
                }
                self.retry_attempts = 0;
                self.backoff_iters = 0;
                self.degrade(exec, &e)
            }
            None => {
                self.failed = Some(format!("{e:#}"));
                Err(e)
            }
        }
    }

    /// Degraded re-planning after a confirmed device loss: requeue
    /// every in-flight request (replayed from its prompt — host
    /// kernels are deterministic and row-independent, so recovered
    /// tokens are bit-identical to an unfaulted run on the degraded
    /// grid), shrink the planner's device set to the survivors, and
    /// resume under the reduced grid. If no grid survives, every
    /// request drains as [`RequestStatus::Failed`] and the engine
    /// latches.
    fn degrade(&mut self, exec: &mut ModelExecutor, cause: &anyhow::Error) -> Result<StepOutcome> {
        let current = self.degraded_n.unwrap_or_else(|| exec.device_count());
        let mut lost: Vec<usize> = exec.crashed_devices().to_vec();
        if lost.is_empty() {
            // Exhausted-retry path: the fault plan never marked a
            // crash, so recover the culprit from the error itself.
            lost.extend(faulted_device(cause));
        }
        let survivors = current.saturating_sub(lost.len().max(1));
        // Grids are power-of-two sized (NodeConfig / SearchSpace
        // invariant): degrade onto the largest power of two that fits.
        let n_new = if survivors == 0 { 0 } else { prev_power_of_two(survivors) };
        if n_new == 0 {
            let reason = format!("all devices lost: {cause:#}");
            self.fail_all_requests(&reason);
            self.failed = Some(reason.clone());
            return Err(anyhow::anyhow!(reason).context("engine failed"));
        }
        // Requeue in-flight work at the head of the backlog (slot
        // order). Partial tokens are discarded: recovery replays each
        // request from its prompt on the degraded grid.
        let mut requeued: Vec<Request> = Vec::new();
        for s in self.slots.iter_mut() {
            if let Some(slot) = s.take() {
                requeued.push(slot.req);
            }
        }
        self.metrics.requests_recovered += requeued.len();
        let requeued_n = requeued.len();
        self.recovered_ids.extend(requeued.iter().map(|r| r.id));
        requeued.append(&mut self.backlog);
        self.backlog = requeued;
        // Tear down the dead session; the next admission re-begins on
        // the degraded grid (the executor rebuilds its device state
        // and reshards weights onto the survivors at begin_session).
        self.active = None;
        self.pending = None;
        self.reset_dwell();
        self.suppress_measured = false;
        self.degraded_n = Some(n_new);
        // Shrink the planner's node: adaptive engines re-solve over
        // the surviving device count, and the plan cache's platform
        // fingerprint changes with it, so stale full-grid plans are
        // never served. Fixed-plan engines fall back to TP(n_new).
        if let Some(cfg) = &mut self.config.adaptive {
            cfg.node = NodeConfig::new(cfg.node.gpu.clone(), n_new);
        }
        // Renumber the fault schedule for the rebuilt grid: activation
        // state clears (the dead device is gone) and events aimed at
        // out-of-range devices or already-passed iterations drop.
        exec.compact_faults(n_new);
        self.metrics.replans_degraded += 1;
        self.record(
            exec,
            EventKind::DegradedReplan { survivors: n_new, requeued: requeued_n },
        );
        let mut out = self.idle_outcome();
        out.switched = true;
        Ok(out)
    }

    /// Drain every queued and in-flight request as a structured
    /// failure (no grid can serve them): their statuses become
    /// [`RequestStatus::Failed`] and the queues empty so drivers
    /// looping on [`Self::idle`] terminate.
    fn fail_all_requests(&mut self, reason: &str) {
        let mut doomed: Vec<Request> = Vec::new();
        for s in self.slots.iter_mut() {
            if let Some(slot) = s.take() {
                doomed.push(slot.req);
            }
        }
        doomed.append(&mut self.backlog);
        let pending = self.router.pending();
        doomed.extend(self.router.take(pending));
        self.metrics.requests_failed += doomed.len();
        self.failed_requests
            .extend(doomed.into_iter().map(|req| (req.id, reason.to_string())));
    }

    /// Coarse health derived from the recovery state machine.
    fn state(&self) -> EngineState {
        if self.failed.is_some() {
            EngineState::Failed
        } else if let Some(n) = self.degraded_n {
            EngineState::Degraded { devices: n }
        } else {
            EngineState::Healthy
        }
    }

    /// Non-blocking admission: a full queue returns a typed
    /// [`SubmitError::QueueFull`] with a deterministic retry hint
    /// instead of running drain iterations (the blocking
    /// [`Self::submit`] behavior, which is unchanged).
    fn try_submit(&mut self, req: Request) -> std::result::Result<RequestId, SubmitError> {
        let id = req.id;
        if self.router.try_submit(req).is_some() {
            let retry_after_iters = self
                .slots
                .iter()
                .flatten()
                .filter(|s| s.decoding())
                .map(|s| s.remaining.max(1))
                .min()
                .unwrap_or(1);
            return Err(SubmitError::QueueFull { retry_after_iters });
        }
        Ok(id)
    }

    /// Cancel a request wherever it lives: queued entries leave the
    /// router/backlog, a running slot is released (KV rows zeroed) and
    /// its partial tokens dropped. Peers are untouched — kernels are
    /// row-independent, so their token streams stay bit-identical.
    /// Finished (or unknown) requests report their current status.
    fn cancel(&mut self, exec: &mut ModelExecutor, id: RequestId) -> Result<RequestStatus> {
        if self.router.remove(id).is_some() {
            self.cancelled_ids.push(id);
            self.record(exec, EventKind::Cancel { request: id });
            return Ok(RequestStatus::Cancelled);
        }
        if let Some(pos) = self.backlog.iter().position(|r| r.id == id) {
            self.backlog.remove(pos);
            self.cancelled_ids.push(id);
            self.record(exec, EventKind::Cancel { request: id });
            return Ok(RequestStatus::Cancelled);
        }
        if let Some(idx) = self
            .slots
            .iter()
            .position(|s| s.as_ref().map_or(false, |slot| slot.req.id == id))
        {
            exec.release_slot(idx)?;
            self.slots[idx] = None;
            self.cancelled_ids.push(id);
            self.record(exec, EventKind::Cancel { request: id });
            return Ok(RequestStatus::Cancelled);
        }
        Ok(self.status(id))
    }

    /// One gang iteration: pack a whole batch and run it to completion
    /// (the legacy `serve_on` loop body, preserved for the compat
    /// wrappers, the PJRT backend, and baseline comparisons).
    fn gang_step(&mut self, exec: &mut ModelExecutor) -> Result<StepOutcome> {
        let mut out = StepOutcome::default();
        if self.router.is_empty() {
            return Ok(out);
        }
        let batch = self.batcher.pack(self.router.take(self.meta.batch));
        // Per-batch strategy selection (adaptive) or the fixed plan.
        let (prefill_plan, decode_plan) = match (&mut self.adapt, &self.config.adaptive) {
            (Some(state), Some(cfg)) => {
                let samples: Vec<TrafficSample> = batch
                    .requests
                    .iter()
                    .map(|req| TrafficSample {
                        prompt: req.prompt.len(),
                        generate: req.max_new_tokens,
                        batch: batch.requests.len(),
                    })
                    .collect();
                let (p, d, decision) = state.select(cfg, &samples, self.last_measured)?;
                if self.recorder.is_enabled() {
                    let clock = Self::fault_clock(exec);
                    if let Some(c) = state.control.last_consult.clone() {
                        self.recorder.record(self.iterations, clock, EventKind::PlanConsult(c));
                    }
                }
                if matches!(decision, SwitchDecision::Switch { .. }) {
                    self.metrics.replans += 1;
                    out.switched = true;
                    if self.recorder.is_enabled() {
                        let clock = Self::fault_clock(exec);
                        let (from, to) = state
                            .control
                            .last_consult
                            .as_ref()
                            .map(|c| {
                                (
                                    c.active.clone().unwrap_or_else(|| "none".to_string()),
                                    c.candidate.clone(),
                                )
                            })
                            .unwrap_or_else(|| ("none".to_string(), String::new()));
                        self.recorder.record(
                            self.iterations,
                            clock,
                            EventKind::Switch { from, to, mode: "gang" },
                        );
                    }
                }
                (p, d)
            }
            _ => (
                ShardPlan::new(self.config.attn, self.config.expert_prefill),
                ShardPlan::new(self.config.attn, self.config.expert_decode),
            ),
        };
        // Declare the batch's plans: evicts stale layouts, materializes
        // missing shards — the measured resharding work of a switch.
        self.trace_reshard(exec, |e| e.begin_batch(&prefill_plan, &decode_plan))?;
        if self.recorder.is_enabled() {
            for (slot, req) in batch.requests.iter().enumerate() {
                self.recorder.record(
                    self.iterations,
                    Self::fault_clock(exec),
                    EventKind::Admit { request: req.id, slot, prompt_tokens: req.prompt.len() },
                );
            }
        }

        // ---- Prefill.
        let snap = self.recorder.is_enabled().then(|| exec.module_times().clone());
        let t0 = Instant::now();
        let logits = exec.prefill(&batch.tokens, &prefill_plan)?;
        let batch_prefill = t0.elapsed().as_secs_f64();
        if let Some(m0) = snap {
            let modules = exec.module_times().delta_since(&m0);
            self.record(
                exec,
                EventKind::PrefillChunk {
                    slot: 0,
                    start: 0,
                    len: self.meta.prefill_len,
                    done: true,
                    secs: batch_prefill,
                    modules,
                },
            );
        }
        self.prefill_time += batch_prefill;
        self.metrics.batches_prefilled += 1;
        if prefill_plan.expert != decode_plan.expert {
            self.metrics.transitions += 1;
        }

        let first = argmax_rows(&logits);
        let first_time = Instant::now();
        let mut generated: Vec<Vec<i32>> =
            (0..batch.live()).map(|slot| vec![first[slot] as i32]).collect();
        let mut last: Vec<i32> = first.iter().map(|&t| t as i32).collect();
        let mut remaining = batch.remaining.clone();
        for r in remaining.iter_mut().take(batch.live()) {
            *r = r.saturating_sub(1);
        }

        // ---- Decode until every live slot finishes (the convoy).
        let t0 = Instant::now();
        while remaining.iter().take(batch.live()).any(|&r| r > 0) {
            let active = remaining.iter().take(batch.live()).filter(|&&r| r > 0).count();
            let snap = self
                .recorder
                .is_enabled()
                .then(|| (Instant::now(), exec.module_times().clone()));
            let logits = exec.decode_step(&last, &decode_plan)?;
            if let Some((it0, m0)) = snap {
                let modules = exec.module_times().delta_since(&m0);
                self.record(
                    exec,
                    EventKind::DecodeStep {
                        decoding: active,
                        capacity: self.meta.batch,
                        secs: it0.elapsed().as_secs_f64(),
                        modules,
                    },
                );
            }
            self.metrics.decode_steps += 1;
            self.metrics.observe_occupancy(active, self.meta.batch);
            // Count live slots, not iterations, so gang and streaming
            // report the same quantity (slot decode steps).
            out.decoded += active;
            let next = argmax_rows(&logits);
            for slot in 0..batch.live() {
                if remaining[slot] > 0 {
                    generated[slot].push(next[slot] as i32);
                    remaining[slot] -= 1;
                }
            }
            last = next.iter().map(|&t| t as i32).collect();
        }
        let batch_decode = t0.elapsed().as_secs_f64();
        self.decode_time += batch_decode;
        // Feed the measured execution of this batch — seconds and the
        // tokens it generated, so the adapt loop can normalize to
        // seconds-per-token — into the next adaptation step (demotes
        // consistently mispredicted plans).
        let batch_tokens: usize = generated.iter().map(|g| g.len()).sum();
        self.last_measured =
            Some(MeasuredLatency::new(batch_prefill + batch_decode, batch_tokens));

        // ---- Retire the whole batch.
        let now = Instant::now();
        for (slot, req) in batch.requests.iter().enumerate() {
            let latency = now.duration_since(req.arrived).as_secs_f64();
            let ttft = first_time.duration_since(req.arrived).as_secs_f64();
            self.metrics.observe_request(latency, ttft, generated[slot].len());
            self.record(
                exec,
                EventKind::Retire {
                    request: req.id,
                    slot,
                    tokens: generated[slot].len(),
                    latency_s: latency,
                    ttft_s: ttft,
                },
            );
            self.responses.push(Response {
                id: req.id,
                tokens: generated[slot].clone(),
                latency,
                ttft,
            });
        }
        out.admitted = batch.live();
        out.retired = batch.live();
        out.queued = self.router.pending();
        Ok(out)
    }

    /// Drop the accumulated dwell window: it measured a plan that is
    /// no longer (or, when a consult just consumed it, no further) the
    /// subject of the next measured hand-off. Every plan-switch path
    /// and the consult itself funnel through this one reset so a
    /// window can never straddle two plans.
    fn reset_dwell(&mut self) {
        self.dwell_seconds = 0.0;
        self.dwell_tokens = 0;
    }

    /// The prefill chunk this slot gets this iteration. Static sizing:
    /// at most `config.prefill_chunk` tokens of the `row_len`-token
    /// padded prompt (0 = unchunked, the whole remaining prompt at
    /// once). Budget sizing (`prefill_budget_ms > 0` under the
    /// micro-chunk pipeline): as many tokens as the **measured**
    /// prefill rate fits into one budget window, so a joiner's chunk
    /// costs about one iteration budget instead of a guessed token
    /// count — falling back to static sizing until the first
    /// measurement lands. Chunk size never affects token values
    /// (ranged prefill is bit-exact at any split), only how admission
    /// latency is amortized across iterations.
    fn chunk_len(&self, row_len: usize, cursor: usize) -> usize {
        let budget_s = self.config.prefill_budget_ms / 1e3;
        let chunk = match self.prefill_rate {
            Some(rate) if budget_s > 0.0 && self.config.pipeline_chunks > 1 && rate > 0.0 => {
                ((rate * budget_s) as usize).max(1)
            }
            _ if self.config.prefill_chunk == 0 => row_len,
            _ => self.config.prefill_chunk,
        };
        chunk.min(row_len - cursor)
    }

    /// Fold one measured prefill call (`tokens` prompt tokens in
    /// `secs` wall seconds) into the budget-sizing rate EWMA. A light
    /// smoothing (α = 0.3) rides out per-call jitter while still
    /// tracking plan switches within a few chunks.
    fn observe_prefill_rate(&mut self, tokens: usize, secs: f64) {
        if secs <= 0.0 || tokens == 0 {
            return;
        }
        let obs = tokens as f64 / secs;
        self.prefill_rate = Some(match self.prefill_rate {
            Some(rate) => 0.7 * rate + 0.3 * obs,
            None => obs,
        });
    }

    /// Run ONE prefill chunk for the Prefilling slot at `idx` — its
    /// first right after admission, or the next at its cursor — and
    /// handle completion: the final chunk's logits are the same
    /// first-token logits a one-shot prefill of the row yields
    /// (chunking is bit-exact), so the first token and TTFT land
    /// there, and a request whose budget is already satisfied retires
    /// on the spot without a decode iteration. The ONE chunk-execution
    /// path shared by the advance loop and the admission step.
    /// Returns whether the slot is still occupied afterwards.
    fn advance_chunk(
        &mut self,
        exec: &mut ModelExecutor,
        idx: usize,
        out: &mut StepOutcome,
    ) -> Result<bool> {
        let (prefill_plan, _) =
            self.active.ok_or(EngineError::NoSession { at: "advance_chunk" })?;
        // Pull the chunk state out to keep the slot borrow short.
        let (row, cursor) = {
            let slot = self.slots[idx]
                .as_mut()
                .ok_or(EngineError::EmptySlot { slot: idx, at: "advance_chunk" })?;
            slot.prefill.take().ok_or(EngineError::NotPrefilling { slot: idx })?
        };
        let c = self.chunk_len(row.len(), cursor);
        let snap = self.recorder.is_enabled().then(|| exec.module_times().clone());
        let t0 = Instant::now();
        let res = exec.prefill_slot(idx, &row[cursor..cursor + c], &prefill_plan);
        let dt = t0.elapsed().as_secs_f64();
        self.prefill_time += dt;
        self.dwell_seconds += dt;
        let logits = match res {
            Ok(logits) => logits,
            Err(e) => {
                // Put the cursor back: without it the slot would read
                // as "decoding" while its KV is only partially written
                // — unretirable when the recovery state machine treats
                // the step error as transient and retries the chunk.
                if let Some(slot) = self.slots[idx].as_mut() {
                    slot.prefill = Some((row, cursor));
                }
                return Err(e);
            }
        };
        self.metrics.prefill_chunks += 1;
        self.observe_prefill_rate(c, dt);
        let done = cursor + c == row.len();
        if let Some(m0) = snap {
            let modules = exec.module_times().delta_since(&m0);
            self.record(
                exec,
                EventKind::PrefillChunk { slot: idx, start: cursor, len: c, done, secs: dt, modules },
            );
        }
        let retire_now = {
            let slot = self.slots[idx]
                .as_mut()
                .ok_or(EngineError::EmptySlot { slot: idx, at: "advance_chunk (post-chunk)" })?;
            if done {
                let first = argmax_rows(&logits)[0] as i32;
                slot.tokens.push(first);
                slot.last = first;
                slot.ttft = slot.req.arrived.elapsed().as_secs_f64();
                // Saturating like the gang path: a zero-budget request
                // still yields its one prefill token.
                slot.remaining = slot.remaining.saturating_sub(1);
                slot.remaining == 0
            } else {
                slot.prefill = Some((row, cursor + c));
                false
            }
        };
        if done {
            self.dwell_tokens += 1;
        }
        if retire_now {
            self.retire_slot(exec, idx, out)?;
            return Ok(false);
        }
        Ok(true)
    }

    /// Batched companion to [`Session::advance_chunk`] for the
    /// micro-chunk pipeline (`pipeline_chunks > 1`): every Prefilling
    /// slot in `group` shares one cursor and one chunk length (the
    /// advance loop groups them so), and the whole group advances in
    /// ONE ranged [`ModelExecutor::prefill_slots`] call — one
    /// fault-clock op, one embed, one fan-out per layer — instead of
    /// `n` sequential single-slot calls. Tokens are bit-identical to
    /// the per-slot path (each slot's rows ride the batch as an
    /// explicit row range). Per-slot completion handling (first token,
    /// TTFT, immediate retirement) mirrors the single-slot path
    /// exactly. Trace accounting: one `PrefillChunk` event per slot,
    /// with the shared call's wall seconds and module deltas carried
    /// by the group's FIRST event only, so summing a trace never
    /// double-counts the batched call. Returns how many slots retired.
    fn advance_chunks(
        &mut self,
        exec: &mut ModelExecutor,
        group: &[usize],
        out: &mut StepOutcome,
    ) -> Result<usize> {
        if group.len() == 1 {
            let still = self.advance_chunk(exec, group[0], out)?;
            return Ok(usize::from(!still));
        }
        let (prefill_plan, _) =
            self.active.ok_or(EngineError::NoSession { at: "advance_chunks" })?;
        // Pull every member's chunk state out to keep slot borrows
        // short; the grouping key guarantees a shared cursor/length.
        let mut states: Vec<(Vec<i32>, usize)> = Vec::with_capacity(group.len());
        for &idx in group {
            let slot = self.slots[idx]
                .as_mut()
                .ok_or(EngineError::EmptySlot { slot: idx, at: "advance_chunks" })?;
            states.push(slot.prefill.take().ok_or(EngineError::NotPrefilling { slot: idx })?);
        }
        let cursor = states[0].1;
        let c = self.chunk_len(states[0].0.len(), cursor);
        let snap = self.recorder.is_enabled().then(|| exec.module_times().clone());
        let rows: Vec<&[i32]> = states.iter().map(|(row, _)| &row[cursor..cursor + c]).collect();
        let t0 = Instant::now();
        let res = exec.prefill_slots(group, &rows, &prefill_plan);
        let dt = t0.elapsed().as_secs_f64();
        self.prefill_time += dt;
        self.dwell_seconds += dt;
        let logits = match res {
            Ok(logits) => logits,
            Err(e) => {
                // Put every cursor back (the single-slot recovery
                // contract): the batched call advanced all members or
                // none, so each slot resumes from its same chunk.
                for (&idx, st) in group.iter().zip(states) {
                    if let Some(slot) = self.slots[idx].as_mut() {
                        slot.prefill = Some(st);
                    }
                }
                return Err(e);
            }
        };
        self.metrics.prefill_chunks += group.len();
        self.observe_prefill_rate(group.len() * c, dt);
        let modules = snap.map(|m0| exec.module_times().delta_since(&m0));
        let mut retired = 0usize;
        for (i, (&idx, (row, _))) in group.iter().zip(states).enumerate() {
            let done = cursor + c == row.len();
            if let Some(all) = &modules {
                let (secs, modules) =
                    if i == 0 { (dt, all.clone()) } else { (0.0, ModuleTimes::default()) };
                self.record(
                    exec,
                    EventKind::PrefillChunk {
                        slot: idx,
                        start: cursor,
                        len: c,
                        done,
                        secs,
                        modules,
                    },
                );
            }
            let retire_now = {
                let slot = self.slots[idx]
                    .as_mut()
                    .ok_or(EngineError::EmptySlot { slot: idx, at: "advance_chunks/post" })?;
                if done {
                    let first = argmax_rows(&logits[i])[0] as i32;
                    slot.tokens.push(first);
                    slot.last = first;
                    slot.ttft = slot.req.arrived.elapsed().as_secs_f64();
                    slot.remaining = slot.remaining.saturating_sub(1);
                    slot.remaining == 0
                } else {
                    slot.prefill = Some((row, cursor + c));
                    false
                }
            };
            if done {
                self.dwell_tokens += 1;
            }
            if retire_now {
                self.retire_slot(exec, idx, out)?;
                retired += 1;
            }
        }
        Ok(retired)
    }

    /// Retire the request occupying `slots[idx]`: free its executor
    /// slot (zeroing its KV rows), record request metrics, and queue
    /// the response for delivery. The one retirement path shared by
    /// the finished-decode, final-chunk, and single-token cases.
    fn retire_slot(
        &mut self,
        exec: &mut ModelExecutor,
        idx: usize,
        out: &mut StepOutcome,
    ) -> Result<()> {
        // Release the executor slot BEFORE taking the entry: if the
        // release itself errors, the slot stays occupied and the
        // request stays pollable (the step error latches the engine,
        // but no request silently vanishes).
        exec.release_slot(idx)?;
        let slot = self.slots[idx]
            .take()
            .ok_or(EngineError::EmptySlot { slot: idx, at: "retire" })?;
        let latency = slot.req.arrived.elapsed().as_secs_f64();
        self.metrics.observe_request(latency, slot.ttft, slot.tokens.len());
        self.record(
            exec,
            EventKind::Retire {
                request: slot.req.id,
                slot: idx,
                tokens: slot.tokens.len(),
                latency_s: latency,
                ttft_s: slot.ttft,
            },
        );
        self.responses.push(Response {
            id: slot.req.id,
            tokens: slot.tokens,
            latency,
            ttft: slot.ttft,
        });
        out.retired += 1;
        Ok(())
    }

    /// One streaming iteration: retire → (apply drained switch) →
    /// advance in-flight chunked prefills → admit + first prefill
    /// chunk → one decode step at per-slot positions.
    fn stream_step(&mut self, exec: &mut ModelExecutor) -> Result<StepOutcome> {
        let mut out = StepOutcome::default();
        let b = self.meta.batch;

        // ---- 1. Retire finished sequences, freeing KV + batch slots.
        // Only decoding slots: a mid-prefill slot with a zero-token
        // budget still needs its final chunk to produce its one token.
        for idx in 0..self.slots.len() {
            let done = self.slots[idx]
                .as_ref()
                .map_or(false, |s| s.remaining == 0 && s.decoding());
            if done {
                self.retire_slot(exec, idx, &mut out)?;
            }
        }
        let mut running = self.slots.iter().filter(|s| s.is_some()).count();

        // ---- 2. An attention-layout switch waited for this safe
        // point: the running set is drained, so the KV sharding can
        // change. Re-begin the session and resume admission.
        if running == 0 {
            if let Some((p, d)) = self.pending.take() {
                self.trace_reshard(exec, |e| e.begin_session(&p, &d))?;
                if self.recorder.is_enabled() {
                    let from = self
                        .active
                        .map(|cur| Self::plans_label(&cur))
                        .unwrap_or_else(|| "none".to_string());
                    self.record(
                        exec,
                        EventKind::Switch {
                            from,
                            to: Self::plans_label(&(p, d)),
                            mode: "drain-applied",
                        },
                    );
                }
                self.active = Some((p, d));
                // The dwell window measured the outgoing plan; the
                // consult that decided this switch already consumed it.
                self.reset_dwell();
                out.switched = true;
            }
        }

        // ---- 3. Advance in-flight chunked prefills: each Prefilling
        // slot gets at most one `prefill_chunk`-token chunk per
        // iteration, so a long-prompt joiner never stalls its peers'
        // decode for a whole prompt. The final chunk's logits are the
        // prompt's first-token logits — the first token (and TTFT)
        // land here. This runs even while an attention-layout switch is
        // pending: prefilling slots are part of the running set that
        // must drain before the switch can apply.
        if self.config.pipeline_chunks > 1 {
            // Micro-chunk pipeline: batch same-(cursor, length) joiner
            // chunks into one ranged prefill call per group. Grouping
            // is a pure function of slot state (BTreeMap keys iterate
            // in ascending cursor order; members keep ascending slot
            // order), so the call sequence — and with it the fault
            // clock — is deterministic for a given request stream.
            let mut groups: std::collections::BTreeMap<(usize, usize), Vec<usize>> =
                std::collections::BTreeMap::new();
            for idx in 0..self.slots.len() {
                if let Some((row, cursor)) =
                    self.slots[idx].as_ref().and_then(|s| s.prefill.as_ref())
                {
                    groups
                        .entry((*cursor, self.chunk_len(row.len(), *cursor)))
                        .or_default()
                        .push(idx);
                }
            }
            for group in groups.values() {
                running -= self.advance_chunks(exec, group, &mut out)?;
            }
        } else {
            for idx in 0..self.slots.len() {
                let prefilling =
                    self.slots[idx].as_ref().map_or(false, |s| s.prefill.is_some());
                if !prefilling {
                    continue;
                }
                if !self.advance_chunk(exec, idx, &mut out)? {
                    running -= 1;
                }
            }
        }

        // ---- 4. Admission boundary: take the joiners, consult the
        // adapt loop on that actual traffic (handing it the measured
        // dwell window since the previous consult), apply safe
        // switches, then run each joiner's FIRST prefill chunk while
        // its peers' KV stays live. Joiners held back by an
        // attention-layout switch wait in the backlog and are admitted
        // first once the drain completes.
        if self.pending.is_none() && running < b {
            let free = b - running;
            let mut joiners = std::mem::take(&mut self.backlog);
            // Joiners re-surfacing from the backlog were already
            // observed by the consult that parked them — only freshly
            // dequeued requests become new traffic samples, so a
            // switch-drain never double-counts them in the window.
            let backlog_n = joiners.len();
            if joiners.len() < free && !self.router.is_empty() {
                joiners.extend(self.router.take(free - joiners.len()));
            }
            if !joiners.is_empty() {
                let desired = match (&mut self.adapt, &self.config.adaptive) {
                    (Some(state), Some(cfg)) => {
                        let concurrency = (running + joiners.len()).min(b);
                        let samples: Vec<TrafficSample> = joiners[backlog_n..]
                            .iter()
                            .map(|r| TrafficSample {
                                prompt: r.prompt.len(),
                                generate: r.max_new_tokens,
                                batch: concurrency,
                            })
                            .collect();
                        // Measured-latency feedback at iteration
                        // granularity: the dwell window (prefill-chunk
                        // + decode seconds, and the tokens they
                        // generated) since the previous consult, all
                        // run under the current active plan. The adapt
                        // loop normalizes it to seconds-per-token, so
                        // streaming and gang observations feed the
                        // same mispredict EWMA.
                        let measured = if self.suppress_measured || self.dwell_tokens == 0 {
                            None
                        } else {
                            Some(MeasuredLatency::new(self.dwell_seconds, self.dwell_tokens))
                        };
                        let (p, d, decision) = state.select(cfg, &samples, measured)?;
                        if self.recorder.is_enabled() {
                            let clock = Self::fault_clock(exec);
                            if let Some(c) = state.control.last_consult.clone() {
                                self.recorder.record(
                                    self.iterations,
                                    clock,
                                    EventKind::PlanConsult(c),
                                );
                            }
                        }
                        // Reset when the window was consumed — or when
                        // it was suppressed (it ran under a forced
                        // plan the controller never adopted, so it is
                        // dropped, not carried). A token-less window
                        // (only prefill chunks ran) keeps accumulating
                        // its seconds toward the next consult instead
                        // of silently losing the plan's measured cost.
                        if measured.is_some() || self.suppress_measured {
                            self.reset_dwell();
                            self.suppress_measured = false;
                        }
                        if matches!(decision, SwitchDecision::Switch { .. }) {
                            self.metrics.replans += 1;
                        }
                        Some((p, d))
                    }
                    _ => None,
                };
                // After a degrade, a fixed-plan engine's configured
                // layout no longer fits the surviving grid: fall back
                // to TP over the survivors (adaptive engines re-plan
                // through the shrunken node instead).
                let fallback = match self.degraded_n {
                    Some(n) => (ShardPlan::tp(n), ShardPlan::tp(n)),
                    None => (
                        ShardPlan::new(self.config.attn, self.config.expert_prefill),
                        ShardPlan::new(self.config.attn, self.config.expert_decode),
                    ),
                };
                let want = desired.unwrap_or_else(|| self.active.unwrap_or(fallback));
                match self.active {
                    None => {
                        // First admission starts the session directly under
                        // the selected plans — no wasted uploads.
                        self.trace_reshard(exec, |e| e.begin_session(&want.0, &want.1))?;
                        if self.recorder.is_enabled() {
                            self.record(
                                exec,
                                EventKind::Switch {
                                    from: "none".to_string(),
                                    to: Self::plans_label(&want),
                                    mode: "session-start",
                                },
                            );
                        }
                        self.active = Some(want);
                    }
                    Some(cur) if cur != want => {
                        if cur.0.attn == want.0.attn {
                            // Expert-only reshard: per-slot KV is untouched,
                            // so in-flight decodes continue under the new
                            // expert layout after the measured weight move.
                            self.trace_reshard(exec, |e| e.begin_batch(&want.0, &want.1))?;
                            if self.recorder.is_enabled() {
                                self.record(
                                    exec,
                                    EventKind::Switch {
                                        from: Self::plans_label(&cur),
                                        to: Self::plans_label(&want),
                                        mode: "expert-reshard",
                                    },
                                );
                            }
                            self.active = Some(want);
                            // Any dwell the consult withheld (token-less
                            // window) measured the outgoing plan — drop
                            // it rather than attribute it to this one.
                            self.reset_dwell();
                            out.switched = true;
                        } else if running == 0 {
                            // The running set is already empty: the KV
                            // sharding can change right now, so apply the
                            // attention-layout switch immediately instead
                            // of burning a dead iteration on the
                            // pending/backlog detour.
                            self.trace_reshard(exec, |e| e.begin_session(&want.0, &want.1))?;
                            if self.recorder.is_enabled() {
                                self.record(
                                    exec,
                                    EventKind::Switch {
                                        from: Self::plans_label(&cur),
                                        to: Self::plans_label(&want),
                                        mode: "session-restart",
                                    },
                                );
                            }
                            self.active = Some(want);
                            self.reset_dwell();
                            out.switched = true;
                        } else {
                            // KV sharding would change under live slots:
                            // stop admitting and drain in-flight decodes
                            // to the safe point.
                            self.pending = Some(want);
                            if self.recorder.is_enabled() {
                                self.record(
                                    exec,
                                    EventKind::Switch {
                                        from: Self::plans_label(&cur),
                                        to: Self::plans_label(&want),
                                        mode: "drain-scheduled",
                                    },
                                );
                            }
                        }
                    }
                    _ => {}
                }
                if self.pending.is_some() {
                    self.backlog = joiners;
                } else {
                    let (prefill_plan, decode_plan) =
                        self.active.ok_or(EngineError::NoSession { at: "admission" })?;
                    let mut joiners = joiners.into_iter();
                    while let Some(req) = joiners.next() {
                        let (row, budget) = self.batcher.pack_one(&req);
                        // Paged KV: admission is bound by free *blocks*,
                        // not free slots. Reserve the request's whole
                        // footprint (prompt + generate budget, rounded
                        // up to blocks) against the pool; when the pool
                        // cannot cover it, the joiner (and everything
                        // behind it — admission order is part of the
                        // deterministic schedule) waits in the backlog
                        // until retirements return blocks.
                        let kv_blocks = match self.config.kv {
                            KvLayout::Paged { block_size, .. } => {
                                let pool = self
                                    .config
                                    .kv
                                    .resolved_blocks(&self.meta)
                                    .expect("paged layout resolves a pool size");
                                let need = (row.len() + budget)
                                    .min(self.meta.max_len)
                                    .div_ceil(block_size);
                                let reserved: usize =
                                    self.slots.iter().flatten().map(|s| s.kv_blocks).sum();
                                if reserved + need > pool {
                                    self.backlog.push(req);
                                    self.backlog.extend(joiners);
                                    break;
                                }
                                need
                            }
                            KvLayout::Padded => 0,
                        };
                        let slot = match exec.claim_slot() {
                            Some(s) => s,
                            None => {
                                // Keep the not-yet-admitted joiners:
                                // they return to the (empty) backlog so
                                // a retried or degraded step re-admits
                                // them instead of losing them.
                                self.backlog.push(req);
                                self.backlog.extend(joiners);
                                return Err(anyhow::anyhow!(
                                    "no free slot for admitted request"
                                ));
                            }
                        };
                        debug_assert!(self.slots[slot].is_none(), "slot maps diverged");
                        // Paged KV: bind the prompt row to the slot and
                        // match it against the DP group's prefix trie —
                        // a hit attaches the shared blocks and moves the
                        // prefill cursor past them (shared prefill work
                        // is skipped; the prompt's final position always
                        // recomputes so first-token logits are exact).
                        let attach = match exec.attach_prompt(slot, &row) {
                            Ok(a) => a,
                            Err(e) => {
                                self.backlog.push(req);
                                self.backlog.extend(joiners);
                                return Err(e);
                            }
                        };
                        if attach.start > 0 {
                            self.metrics.prefix_hits += 1;
                            self.metrics.prefix_shared_tokens += attach.start as u64;
                            self.record(
                                exec,
                                EventKind::PrefixHit {
                                    request: req.id,
                                    slot,
                                    shared_tokens: attach.start,
                                    shared_blocks: attach.shared_blocks,
                                },
                            );
                        }
                        self.record(
                            exec,
                            EventKind::Admit {
                                request: req.id,
                                slot,
                                prompt_tokens: req.prompt.len(),
                            },
                        );
                        self.metrics.batches_prefilled += 1;
                        if prefill_plan.expert != decode_plan.expert {
                            self.metrics.transitions += 1;
                        }
                        out.admitted += 1;
                        // Every joiner enters in the Prefilling phase at
                        // its attach cursor (0 unless a prefix hit) and
                        // runs its first chunk right away;
                        // `advance_chunk` promotes it to Decoding (or
                        // retires a single-token request) if that chunk
                        // already completes the prompt — the unchunked
                        // configuration in one step.
                        self.slots[slot] = Some(Slot {
                            req,
                            tokens: Vec::new(),
                            last: 0,
                            remaining: budget,
                            ttft: 0.0,
                            prefill: Some((row, attach.start)),
                            kv_blocks,
                        });
                        match self.advance_chunk(exec, slot, &mut out) {
                            Ok(true) => running += 1,
                            Ok(false) => {}
                            Err(e) => {
                                // The faulted joiner stays in its slot
                                // (cursor restored — retryable); the
                                // rest go back to the backlog rather
                                // than being dropped with the iterator.
                                self.backlog.extend(joiners);
                                return Err(e);
                            }
                        }
                    }
                }
            }
        }

        // ---- 5. One decode iteration for the decoding slots. Slots
        // still chunk-prefilling ride this iteration inert (the
        // executor skips their KV and position).
        let decoding = self.slots.iter().flatten().filter(|s| s.decoding()).count();
        if decoding > 0 {
            let (_, decode_plan) =
                self.active.ok_or(EngineError::NoSession { at: "decode" })?;
            let mut last = vec![0i32; b];
            for (i, s) in self.slots.iter().enumerate() {
                if let Some(slot) = s {
                    if slot.decoding() {
                        last[i] = slot.last;
                    }
                }
            }
            let snap = self.recorder.is_enabled().then(|| exec.module_times().clone());
            let t0 = Instant::now();
            let logits = exec.decode_slots(&last, &decode_plan)?;
            let dt = t0.elapsed().as_secs_f64();
            self.decode_time += dt;
            self.dwell_seconds += dt;
            self.metrics.decode_steps += 1;
            self.metrics.observe_occupancy(decoding, b);
            if let Some(m0) = snap {
                let modules = exec.module_times().delta_since(&m0);
                self.record(
                    exec,
                    EventKind::DecodeStep { decoding, capacity: b, secs: dt, modules },
                );
            }
            let next = argmax_rows(&logits);
            for (i, s) in self.slots.iter_mut().enumerate() {
                if let Some(slot) = s {
                    if !slot.decoding() {
                        continue;
                    }
                    if slot.remaining > 0 {
                        slot.tokens.push(next[i] as i32);
                        slot.remaining -= 1;
                    }
                    slot.last = next[i] as i32;
                }
            }
            self.dwell_tokens += decoding;
            out.decoded = decoding;
        }

        // ---- 6. Paged-KV accounting: mirror the pool gauges into the
        // metrics registry and record this iteration's alloc/free
        // deltas as block-level trace events.
        if let Some(stats) = exec.paged_stats() {
            self.metrics.kv_blocks_in_use = stats.blocks_in_use as u64;
            self.metrics.kv_blocks_free = stats.blocks_free as u64;
            if stats.allocs < self.kv_allocs_seen || stats.frees < self.kv_frees_seen {
                // A session restart rebuilt the pool: its counters
                // restarted below the watermarks, so the deltas do too.
                self.kv_allocs_seen = 0;
                self.kv_frees_seen = 0;
            }
            if self.recorder.is_enabled() {
                let allocs = stats.allocs - self.kv_allocs_seen;
                if allocs > 0 {
                    self.record(
                        exec,
                        EventKind::BlockAlloc {
                            blocks: allocs as usize,
                            in_use: stats.blocks_in_use,
                            free: stats.blocks_free,
                        },
                    );
                }
                let frees = stats.frees - self.kv_frees_seen;
                if frees > 0 {
                    self.record(
                        exec,
                        EventKind::BlockFree {
                            blocks: frees as usize,
                            in_use: stats.blocks_in_use,
                            free: stats.blocks_free,
                        },
                    );
                }
            }
            self.kv_allocs_seen = stats.allocs;
            self.kv_frees_seen = stats.frees;
        }

        out.running = self.slots.iter().filter(|s| s.is_some()).count();
        out.queued = self.router.pending() + self.backlog.len();
        Ok(out)
    }

    /// Request a plan change (fixed-plan engines; adaptive engines
    /// re-select at every admission boundary anyway). Applied at the
    /// next safe point: immediately for expert-only switches, after the
    /// running set drains for attention-layout changes, at the next
    /// batch for the gang scheduler.
    fn request_plans(
        &mut self,
        exec: &mut ModelExecutor,
        prefill: ShardPlan,
        decode: ShardPlan,
    ) -> Result<()> {
        exec.validate(&prefill)?;
        exec.validate(&decode)?;
        if prefill.attn != decode.attn {
            anyhow::bail!(
                "attention strategy must match across stages ({} vs {})",
                prefill.attn,
                decode.attn
            );
        }
        // Keep the fixed fallback in sync so a not-yet-started session
        // (or the gang scheduler's next batch) picks the new plans up.
        self.config.attn = prefill.attn;
        self.config.expert_prefill = prefill.expert;
        self.config.expert_decode = decode.expert;
        // The latest request supersedes any switch still waiting on a
        // drain — otherwise a stale pending plan would pop at the next
        // safe point and silently revert this one. The drain-wait
        // branch below re-queues when these plans themselves must wait.
        let cancelled = self.pending.take().is_some();
        match self.active {
            Some(cur) if cur == (prefill, decode) => {
                if cancelled {
                    // A controller-decided switch was cancelled while
                    // the controller already adopted its plan: the
                    // session keeps executing the old layout, so the
                    // dwell window must not feed the (never-applied)
                    // adopted plan's mispredict EWMA.
                    self.reset_dwell();
                    self.suppress_measured = true;
                }
            }
            Some(cur) if cur.0.attn == prefill.attn => {
                self.trace_reshard(exec, |e| e.begin_batch(&prefill, &decode))?;
                if self.recorder.is_enabled() {
                    self.record(
                        exec,
                        EventKind::Switch {
                            from: Self::plans_label(&cur),
                            to: Self::plans_label(&(prefill, decode)),
                            mode: "forced",
                        },
                    );
                }
                self.active = Some((prefill, decode));
                // The dwell window measured the outgoing plan; don't
                // let it be attributed to the new one. And because the
                // session plan was forced out from under an adaptive
                // controller, the NEXT window (run under the forced
                // plan) must not feed the controller's still-active
                // plan's EWMA either.
                self.reset_dwell();
                self.suppress_measured = true;
            }
            Some(cur) if self.slots.iter().all(|s| s.is_none()) => {
                // Attention-layout switch with the running set already
                // empty: the KV sharding can change right now, so
                // re-begin the session instead of burning an iteration
                // on the pending/drain detour.
                self.trace_reshard(exec, |e| e.begin_session(&prefill, &decode))?;
                if self.recorder.is_enabled() {
                    self.record(
                        exec,
                        EventKind::Switch {
                            from: Self::plans_label(&cur),
                            to: Self::plans_label(&(prefill, decode)),
                            mode: "forced",
                        },
                    );
                }
                self.active = Some((prefill, decode));
                self.reset_dwell();
                self.suppress_measured = true;
            }
            Some(cur) => {
                self.pending = Some((prefill, decode));
                self.suppress_measured = true;
                if self.recorder.is_enabled() {
                    self.record(
                        exec,
                        EventKind::Switch {
                            from: Self::plans_label(&cur),
                            to: Self::plans_label(&(prefill, decode)),
                            mode: "forced",
                        },
                    );
                }
            }
            None => {}
        }
        Ok(())
    }

    fn status(&self, id: RequestId) -> RequestStatus {
        if let Some(resp) = self.responses.iter().rev().find(|r| r.id == id) {
            return RequestStatus::Finished(resp.clone());
        }
        for s in self.slots.iter().flatten() {
            if s.req.id == id {
                return RequestStatus::Running { tokens: s.tokens.clone() };
            }
        }
        if self.router.contains(id) || self.backlog.iter().any(|r| r.id == id) {
            return RequestStatus::Queued;
        }
        if self.cancelled_ids.contains(&id) {
            return RequestStatus::Cancelled;
        }
        if let Some((_, reason)) = self.failed_requests.iter().find(|(r, _)| *r == id) {
            return RequestStatus::Failed { reason: reason.clone() };
        }
        RequestStatus::Unknown
    }

    fn idle(&self) -> bool {
        self.router.is_empty()
            && self.backlog.is_empty()
            && self.slots.iter().all(|s| s.is_none())
    }

    fn run_to_idle(&mut self, exec: &mut ModelExecutor) -> Result<()> {
        while !self.idle() {
            self.step(exec)?;
        }
        Ok(())
    }

    fn take_undelivered(&mut self) -> Vec<Response> {
        let out = self.responses[self.delivered..].to_vec();
        self.delivered = self.responses.len();
        out
    }

    /// Close the books: wall time, executor upload/reshard deltas, plan
    /// cache persistence — the same accounting the old loop did.
    fn finish(mut self, exec: &ModelExecutor) -> Result<ServeReport> {
        // Set-once semantics: a second close of the books (or a
        // zero-elapsed clock) can never zero the throughput of a
        // completed run.
        self.metrics.finalize_wall(self.run_start.elapsed().as_secs_f64());
        let stats = exec.stats();
        self.metrics.weight_uploads = stats.materializations - self.stats0.materializations;
        self.metrics.reshards = stats.reshards - self.stats0.reshards;
        self.metrics.reshard_time = stats.reshard_seconds - self.stats0.reshard_seconds;
        if let (Some(state), Some(cfg)) = (&self.adapt, &self.config.adaptive) {
            if let Some(path) = &cfg.plan_cache {
                if let Err(e) = state.control.cache.save(path) {
                    eprintln!("could not save plan cache {}: {e:#}", path.display());
                }
            }
        }
        let telemetry = self.metrics.registry();
        let trace = self.recorder.take_events();
        Ok(ServeReport {
            metrics: self.metrics,
            responses: self.responses,
            prefill_time: self.prefill_time,
            decode_time: self.decode_time,
            telemetry,
            trace,
        })
    }
}

/// Largest power of two `<= n` (n >= 1) — the grid size a degraded
/// device set rounds down to.
fn prev_power_of_two(n: usize) -> usize {
    debug_assert!(n >= 1);
    1usize << (usize::BITS - 1 - n.leading_zeros())
}

/// Serve a whole workload on a **caller-owned** executor under the
/// given scheduling mode, to completion. This is the engine core the
/// deprecated [`super::serve_on`]/[`super::serve_workload`] wrappers
/// call with [`Scheduling::Gang`]; pass [`Scheduling::Streaming`] to
/// run continuous batching over an executor you keep across runs.
pub fn serve_with(
    exec: &mut ModelExecutor,
    config: &ServeConfig,
    scheduling: Scheduling,
    workload: Vec<Request>,
) -> Result<ServeReport> {
    serve_with_recorder(exec, config, scheduling, workload, Recorder::disabled())
}

/// [`serve_with`] plus a caller-supplied trace recorder: every
/// scheduler iteration's events (admissions, prefill chunks, decode
/// steps, plan consults, switches, faults, retirements) are recorded
/// deterministically and returned in the report's `trace` field.
pub fn serve_with_recorder(
    exec: &mut ModelExecutor,
    config: &ServeConfig,
    scheduling: Scheduling,
    workload: Vec<Request>,
    recorder: Recorder,
) -> Result<ServeReport> {
    exec.set_quant(config.quant)?;
    exec.set_pipeline_chunks(config.pipeline_chunks)?;
    if config.kv.is_paged() && scheduling != Scheduling::Streaming {
        anyhow::bail!(
            "paged KV serves the streaming scheduler only: gang prefill owns whole \
             padded batches (use streaming scheduling, or the padded layout)"
        );
    }
    exec.set_kv_layout(config.kv)?;
    let mut session = Session::new(exec, config.clone(), scheduling);
    session.recorder = recorder;
    for req in workload {
        session.submit(exec, req)?;
    }
    session.run_to_idle(exec)?;
    session.finish(exec)
}

/// Typed constructor for [`Engine`]: serving config (fixed plan or
/// adaptive policy, router policy, queue capacity) plus the scheduling
/// mode, then a backend.
pub struct EngineBuilder {
    config: ServeConfig,
    scheduling: Scheduling,
    fault: Option<FaultPlan>,
    recorder: Option<Recorder>,
}

impl EngineBuilder {
    /// Replace the whole serving config.
    pub fn config(mut self, config: ServeConfig) -> EngineBuilder {
        self.config = config;
        self
    }

    /// Scheduling mode (default: streaming).
    pub fn scheduling(mut self, scheduling: Scheduling) -> EngineBuilder {
        self.scheduling = scheduling;
        self
    }

    /// Router queue capacity.
    pub fn queue_capacity(mut self, capacity: usize) -> EngineBuilder {
        self.config.queue_capacity = capacity;
        self
    }

    /// Router queue discipline.
    pub fn policy(mut self, policy: super::router::RouterPolicy) -> EngineBuilder {
        self.config.policy = policy;
        self
    }

    /// Max prompt tokens prefilled per joiner per streaming iteration
    /// (0 = unchunked). See [`ServeConfig::prefill_chunk`].
    pub fn prefill_chunk(mut self, tokens: usize) -> EngineBuilder {
        self.config.prefill_chunk = tokens;
        self
    }

    /// Micro-chunk pipeline width `K` on the host executor (default 1
    /// = module-sequential). See [`ServeConfig::pipeline_chunks`].
    pub fn pipeline_chunks(mut self, chunks: usize) -> EngineBuilder {
        self.config.pipeline_chunks = chunks;
        self
    }

    /// Budget-driven prefill chunk sizing in milliseconds (default 0 =
    /// static `prefill_chunk` sizing). See
    /// [`ServeConfig::prefill_budget_ms`].
    pub fn prefill_budget_ms(mut self, ms: f64) -> EngineBuilder {
        self.config.prefill_budget_ms = ms;
        self
    }

    /// Online-adaptive plan selection (consulted per admission
    /// boundary in streaming mode, per batch in gang mode).
    pub fn adaptive(mut self, adaptive: AdaptiveServing) -> EngineBuilder {
        self.config.adaptive = Some(adaptive);
        self
    }

    /// Install a deterministic device-fault schedule on the engine's
    /// executor (host backends only) — chaos testing and the fault
    /// recovery benches. See [`crate::model::FaultPlan`].
    pub fn fault_plan(mut self, plan: FaultPlan) -> EngineBuilder {
        self.fault = Some(plan);
        self
    }

    /// Install a deterministic trace recorder: every scheduler
    /// iteration's events are recorded (keyed on the iteration and
    /// executor fault-clock counters — wall time is payload only) and
    /// returned in the shutdown report's `trace`; [`Engine::trace`]
    /// exposes the stream mid-run.
    pub fn recorder(mut self, recorder: Recorder) -> EngineBuilder {
        self.recorder = Some(recorder);
        self
    }

    /// Artifact-free engine on the host grid kernels.
    pub fn build_host(self, weights: WeightStore) -> Engine<'static> {
        self.build_host_with_mode(weights, EngineMode::Parallel)
    }

    /// Host engine with an explicit per-device scheduling mode (the
    /// sequential mode is the bit-equivalence reference path).
    pub fn build_host_with_mode(self, weights: WeightStore, mode: EngineMode) -> Engine<'static> {
        let mut exec = ModelExecutor::host_with_mode(weights, mode);
        if let Some(plan) = self.fault {
            exec.set_fault_plan(plan);
        }
        // Infallible on a fresh host executor (blocked kernels, no
        // resident shards yet).
        exec.set_quant(self.config.quant)
            .expect("host executor accepts the configured quantization");
        assert!(
            !(self.config.kv.is_paged() && self.scheduling != Scheduling::Streaming),
            "paged KV serves the streaming scheduler only (gang prefill owns whole padded batches)"
        );
        exec.set_kv_layout(self.config.kv)
            .expect("host executor accepts the configured KV layout");
        exec.set_pipeline_chunks(self.config.pipeline_chunks)
            .expect("the pipeline needs at least one micro-chunk (pipeline_chunks >= 1)");
        let mut session = Session::new(&exec, self.config, self.scheduling);
        if let Some(recorder) = self.recorder {
            session.recorder = recorder;
        }
        Engine { exec, session }
    }

    /// PJRT-artifact engine. Gang scheduling only: the fixed-shape
    /// artifacts take one scalar decode position per batch, which
    /// cannot express the streaming engine's per-slot offsets.
    pub fn build_pjrt(self, rt: &PjrtRuntime) -> Result<Engine<'_>> {
        if self.scheduling == Scheduling::Streaming {
            anyhow::bail!(
                "streaming scheduling is host-backend only: the fixed-shape PJRT artifacts \
                 pin one scalar decode position per batch (use --engine gang, or the host \
                 backend)"
            );
        }
        if self.fault.is_some() {
            anyhow::bail!(
                "fault injection is host-backend only: the fault plan hooks the host \
                 executor's per-op device map"
            );
        }
        if self.config.quant.is_some() {
            anyhow::bail!(
                "quantized serving is host-backend only: the PJRT artifacts consume f32 \
                 shard tensors (drop --quant, or use --backend host)"
            );
        }
        if self.config.kv.is_paged() {
            anyhow::bail!(
                "paged KV is host-backend only: the fixed-shape PJRT artifacts address \
                 contiguous padded KV rows (drop --kv paged, or use --backend host)"
            );
        }
        if self.config.pipeline_chunks > 1 {
            anyhow::bail!(
                "micro-chunk pipelining is host-backend only: the PJRT artifacts are \
                 monolithic full-batch programs (drop --pipeline-chunks, or use --backend host)"
            );
        }
        let exec = ModelExecutor::new(rt)?;
        let mut session = Session::new(&exec, self.config, self.scheduling);
        if let Some(recorder) = self.recorder {
            session.recorder = recorder;
        }
        Ok(Engine { exec, session })
    }
}

/// The long-lived serving engine: owns the [`ModelExecutor`] (weight
/// shards and per-slot KV stay device-resident across requests) and the
/// iteration scheduler. See the module docs for the step anatomy.
pub struct Engine<'rt> {
    exec: ModelExecutor<'rt>,
    session: Session,
}

impl<'rt> Engine<'rt> {
    /// Start building an engine from a serving config.
    pub fn builder(config: ServeConfig) -> EngineBuilder {
        EngineBuilder { config, scheduling: Scheduling::Streaming, fault: None, recorder: None }
    }

    /// Enqueue a request (backpressures by running scheduler iterations
    /// when the queue is full — never drops or aborts).
    pub fn submit(&mut self, req: Request) -> Result<RequestId> {
        self.session.submit(&mut self.exec, req)
    }

    /// Run ONE scheduler iteration (retire → admit/prefill → decode).
    /// Non-blocking: returns immediately with what it did; an idle
    /// outcome means there is nothing left to schedule.
    pub fn step(&mut self) -> Result<StepOutcome> {
        self.session.step(&mut self.exec)
    }

    /// Non-blocking admission: returns a typed
    /// [`SubmitError::QueueFull`] (with a deterministic
    /// retry-after-iterations hint) instead of running drain
    /// iterations when the queue is full. [`Engine::submit`]'s
    /// blocking drain semantics are unchanged.
    pub fn try_submit(&mut self, req: Request) -> std::result::Result<RequestId, SubmitError> {
        self.session.try_submit(req)
    }

    /// Cancel a request wherever it lives (queue, backlog, or a live
    /// slot — whose KV rows are zeroed). Peers are untouched; their
    /// token streams stay bit-identical. Returns
    /// [`RequestStatus::Cancelled`] on removal, or the request's
    /// current status when there was nothing to cancel.
    pub fn cancel(&mut self, id: RequestId) -> Result<RequestStatus> {
        self.session.cancel(&mut self.exec, id)
    }

    /// Coarse engine health: `Healthy`, `Degraded` after a confirmed
    /// device loss shrank the grid, or `Failed` once a fatal error
    /// latched (see the module docs' recovery state machine).
    pub fn state(&self) -> EngineState {
        self.session.state()
    }

    /// Ids of requests recovered by degraded re-planning (requeued and
    /// replayed from their prompt), in recovery order.
    pub fn recovered(&self) -> &[RequestId] {
        &self.session.recovered_ids
    }

    /// Non-blocking progress query for a submitted request.
    pub fn poll(&self, id: RequestId) -> RequestStatus {
        self.session.status(id)
    }

    /// Collect the responses finished since the last `drain` —
    /// non-blocking streaming delivery, no scheduler work is run.
    /// Responses handed out here are not repeated by later `drain`
    /// calls; `shutdown`'s report still carries everything.
    pub fn drain(&mut self) -> Vec<Response> {
        self.session.take_undelivered()
    }

    /// Run scheduler iterations until all submitted work completes
    /// (the blocking companion to `drain`; `shutdown` does this and
    /// also closes the books).
    pub fn run_to_completion(&mut self) -> Result<()> {
        self.session.run_to_idle(&mut self.exec)
    }

    /// Request a (prefill, decode) plan switch, applied at the next
    /// safe point (see [`Session::request_plans`] semantics in the
    /// module docs). Intended for fixed-plan engines; adaptive engines
    /// re-select at every admission boundary.
    pub fn force_plans(&mut self, prefill: ShardPlan, decode: ShardPlan) -> Result<()> {
        self.session.request_plans(&mut self.exec, prefill, decode)
    }

    /// Metrics accumulated so far (finalized by `shutdown`).
    pub fn metrics(&self) -> &Metrics {
        &self.session.metrics
    }

    /// The trace events recorded so far (empty unless the engine was
    /// built with [`EngineBuilder::recorder`]; `shutdown`'s report
    /// takes ownership of the full stream).
    pub fn trace(&self) -> &[TraceEvent] {
        self.session.recorder.events()
    }

    /// The adaptation loop, when this engine was built with an
    /// adaptive config — read-only access to the traffic window, plan
    /// cache, and controller (e.g. its measured mispredict EWMAs).
    pub fn adapt(&self) -> Option<&AdaptLoop> {
        self.session.adapt.as_ref().map(|state| &state.control)
    }

    /// The underlying executor (shard/upload accounting lives here).
    pub fn executor(&self) -> &ModelExecutor<'rt> {
        &self.exec
    }

    /// Finish all submitted work and return the run report — the same
    /// [`ServeReport`] the deprecated free functions produced.
    pub fn shutdown(mut self) -> Result<ServeReport> {
        self.session.run_to_idle(&mut self.exec)?;
        self.session.finish(&self.exec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DeviceGrid;

    #[test]
    fn adaptive_selection_returns_native_grid_plans() {
        // The adaptation loop needs no runtime: feed it an admission
        // boundary's samples and check it lands on plans that lower to
        // well-formed device grids at the node's device count — the
        // planner's pick is executed natively (hybrid EP×TP included),
        // never projected onto a pure layout.
        let config = ServeConfig::adaptive(4);
        let acfg = config.adaptive.as_ref().unwrap();
        let mut state = AdaptState::new(acfg);
        let samples: Vec<TrafficSample> =
            (0..4).map(|_| TrafficSample { prompt: 24, generate: 16, batch: 4 }).collect();
        let (pre, dec, decision) = state.select(acfg, &samples, None).unwrap();
        assert_eq!(decision, SwitchDecision::Adopt);
        assert_eq!(pre.attn, dec.attn, "attention is pinned across stages");
        for plan in [&pre, &dec] {
            assert_eq!(plan.devices(), 4);
            let grid = DeviceGrid::lower(plan).unwrap();
            let m = acfg.model.clone();
            grid.check_dims(m.q_heads, m.kv_heads, m.num_experts, m.moe_inter_size, 4)
                .unwrap();
        }
        assert!(state.control.controller.active().is_some());
        // A second identical boundary is a cache hit, not a re-solve.
        state.select(acfg, &samples, None).unwrap();
        assert_eq!(state.control.cache.hits, 1);
        assert_eq!(state.control.cache.misses, 1);
    }

    #[test]
    fn streaming_engine_smoke_submit_step_poll_drain() {
        let m = TinyModelMeta::host_demo();
        let weights = WeightStore::synthetic(&m, 5);
        let mut engine = Engine::builder(ServeConfig::tp(4))
            .build_host_with_mode(weights, EngineMode::Sequential);
        let id0 = engine.submit(Request::new(0, vec![1, 2, 3], 3)).unwrap();
        let id1 = engine.submit(Request::new(1, vec![4, 5], 5)).unwrap();
        assert!(matches!(engine.poll(id0), RequestStatus::Queued));
        let out = engine.step().unwrap();
        assert_eq!(out.admitted, 2);
        assert_eq!(out.running, 2);
        assert_eq!(out.decoded, 2);
        match engine.poll(id0) {
            RequestStatus::Running { tokens } => assert_eq!(tokens.len(), 2),
            other => panic!("expected running, got {other:?}"),
        }
        // id0 needs 3 tokens: 1 from prefill + 2 decodes, then a retire
        // step; id1 runs longer.
        engine.run_to_completion().unwrap();
        let responses = engine.drain();
        assert_eq!(responses.len(), 2);
        assert!(matches!(engine.poll(id0), RequestStatus::Finished(_)));
        assert!(matches!(engine.poll(id1), RequestStatus::Finished(_)));
        assert!(matches!(engine.poll(99), RequestStatus::Unknown));
        assert!(engine.drain().is_empty(), "drain repeats responses");
        let report = engine.shutdown().unwrap();
        assert_eq!(report.metrics.requests_completed, 2);
        assert_eq!(report.responses.len(), 2, "shutdown report keeps everything");
        let tokens: Vec<usize> = report.responses.iter().map(|r| r.tokens.len()).collect();
        assert!(tokens.contains(&3) && tokens.contains(&5));
    }
}
