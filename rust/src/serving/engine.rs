//! The serving `Engine`: a long-lived session facade over the grid
//! executor with **continuous batching** and in-flight hybrid plan
//! switches — the public serving API.
//!
//! The previous surface (`serve_workload`/`serve_on` free functions)
//! gang-scheduled a fixed batch through prefill and decoded until the
//! *slowest* member finished, so short requests convoyed behind long
//! ones and the adapt loop only saw traffic at coarse batch
//! boundaries. The `Engine` runs an Orca-style iteration scheduler
//! instead:
//!
//! 1. **retire** — finished sequences leave the live batch
//!    ([`crate::model::ModelExecutor::release_slot`]), freeing their KV
//!    slot mid-decode;
//! 2. **advance + admit** — slots mid-way through a **chunked
//!    prefill** advance by one chunk, and queued requests claim freed
//!    slots and run their first chunk
//!    ([`crate::model::ModelExecutor::prefill_slot`]) while their
//!    peers keep decoding;
//! 3. **decode** — one step for the fully-prefilled running set at
//!    per-slot positions
//!    ([`crate::model::ModelExecutor::decode_slots`]).
//!
//! One [`Engine::step`] call runs one such iteration; [`Engine::submit`]
//! enqueues work (with drain-based backpressure instead of the old
//! hard `bail!` on a full queue), [`Engine::poll`]/[`Engine::drain`]
//! deliver tokens, and [`Engine::shutdown`] returns the familiar
//! [`ServeReport`].
//!
//! **Chunked prefill** ([`ServeConfig::prefill_chunk`]). With a
//! non-zero chunk, a joiner's padded prompt is prefilled at most
//! `prefill_chunk` tokens per iteration through the executor's
//! *resumable* `prefill_slot` (ranged attention writing KV at the
//! slot's cursor), so a long-prompt joiner no longer stalls its peers'
//! decode step for a whole prompt — peer decode iterations interleave
//! between chunks. A slot in the *Prefilling* phase takes no decode
//! steps and emits its first token only when the final chunk's logits
//! land (TTFT is measured there); causal attention makes the chunked
//! computation bit-identical to a one-shot prefill, so per-request
//! tokens still match the gang scheduler exactly. `0` (the default)
//! keeps the one-iteration-per-prompt behavior.
//!
//! **Plan switches at iteration granularity.** With an adaptive config,
//! the adapt loop ([`crate::adapt::AdaptLoop`] via [`AdaptState`]) is
//! consulted at every admission boundary instead of once per gang
//! batch. A switch that keeps the attention layout (expert resharding —
//! the common HAP transition) applies immediately: per-slot KV caches
//! are untouched, so in-flight decodes continue under the new expert
//! layout while the executor's measured reshard moves the expert
//! weights. A switch that changes the attention layout invalidates the
//! KV sharding, so the engine stops admitting, drains in-flight decodes
//! to the safe point (running set empty), re-begins the session under
//! the new layout, and resumes admission — or applies on the spot when
//! the running set is already empty at decision time.
//!
//! **Measured feedback at iteration granularity.** The session
//! aggregates each iteration's wall time (prefill chunks + decode
//! steps) and the tokens it generated into a per-plan dwell
//! accumulator; at every admission-boundary consult the accumulated
//! [`MeasuredLatency`] is handed to the adapt loop, which normalizes
//! it — and the planner's prediction for the same traffic key — to
//! **seconds per generated token** before folding the ratio into the
//! controller's mispredict EWMA. Gang mode feeds whole-batch
//! observations through the same normalized API, so both schedulers
//! demote consistently mispredicted plans with commensurable units and
//! the streaming path's controller is no longer blind
//! (`measured: None`) where adaptation actually happens.
//!
//! **Equivalence.** Every kernel in the host stack is row-independent,
//! so a sequence's tokens depend only on its own (padded) prompt and
//! the weights — never on which peers share the batch. Streaming
//! scheduling therefore produces per-request token sequences
//! bit-identical to the gang path (`rust/tests/engine_api.rs`).
//!
//! The gang scheduler is retained behind [`Scheduling::Gang`] — it is
//! what the deprecated `serve_workload`/`serve_on` wrappers run, the
//! only mode the fixed-shape PJRT artifacts support, and the baseline
//! `hap serve --engine gang` compares against.

use super::batcher::Batcher;
use super::metrics::Metrics;
use super::router::Router;
use super::server::{AdaptiveServing, ServeConfig, ServeReport};
use super::{Request, Response};
use crate::adapt::window::TrafficSample;
use crate::adapt::{AdaptLoop, MeasuredLatency, PlanCache, SwitchDecision};
use crate::model::{EngineMode, ExecStats, ModelExecutor, ShardPlan, WeightStore};
use crate::planner::{HapPlanner, PLANNER_SEED};
use crate::runtime::literal::argmax_rows;
use crate::runtime::{PjrtRuntime, TinyModelMeta};
use crate::Result;
use std::time::Instant;

/// Requests are identified by their caller-assigned `Request::id`
/// (unique per engine; `poll` looks them up by it).
pub type RequestId = u64;

/// How the engine schedules work across the batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheduling {
    /// Pack a batch, prefill once, decode until the slowest member
    /// finishes (the legacy run-to-completion path; required by the
    /// fixed-shape PJRT artifacts).
    Gang,
    /// Continuous batching: retire/admit/decode every iteration with
    /// per-slot KV positions (host backend).
    Streaming,
}

impl Scheduling {
    pub fn parse(s: &str) -> Option<Scheduling> {
        match s {
            "gang" => Some(Scheduling::Gang),
            "streaming" => Some(Scheduling::Streaming),
            _ => None,
        }
    }
}

/// What one [`Engine::step`] did.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StepOutcome {
    /// Requests admitted (chunked-prefilled) this iteration.
    pub admitted: usize,
    /// Requests retired (responses now pollable).
    pub retired: usize,
    /// Slot decode steps taken: live slots summed over the decode
    /// iterations this step ran — one iteration in streaming mode, the
    /// whole batch's convoy in gang mode — so both schedulers report
    /// the same quantity.
    pub decoded: usize,
    /// Live slots after the iteration.
    pub running: usize,
    /// Requests still queued after the iteration.
    pub queued: usize,
    /// A plan switch was applied (reshard or session restart).
    pub switched: bool,
}

impl StepOutcome {
    /// True when the step found nothing to do.
    pub fn idle(&self) -> bool {
        self.admitted == 0 && self.retired == 0 && self.decoded == 0 && self.running == 0
    }
}

/// Non-blocking per-request progress (see [`Engine::poll`]).
#[derive(Debug, Clone)]
pub enum RequestStatus {
    /// Waiting in the admission queue.
    Queued,
    /// In a batch slot; `tokens` generated so far.
    Running { tokens: Vec<i32> },
    /// Complete; the full response.
    Finished(Response),
    /// Never submitted (or submitted to a different engine).
    Unknown,
}

/// Per-run state of the adaptation loop: the shared [`AdaptLoop`] (the
/// exact implementation the replay acceptance tests validate) plus the
/// platform's latency model, resolved once so the per-consult path
/// never touches the global model-cache lock.
pub(crate) struct AdaptState {
    pub(crate) control: AdaptLoop,
    latency: std::sync::Arc<crate::sim::LatencyModel>,
}

impl AdaptState {
    pub(crate) fn new(cfg: &AdaptiveServing) -> AdaptState {
        let mut control = AdaptLoop::new(cfg.controller.clone(), cfg.window_capacity);
        if let Some(path) = &cfg.plan_cache {
            match PlanCache::load(path, &cfg.model, &cfg.node) {
                Ok(cache) => control.cache = cache,
                Err(e) => eprintln!("plan cache {}: {e:#} (starting cold)", path.display()),
            }
        }
        AdaptState {
            control,
            latency: crate::sim::LatencyModel::cached(&cfg.node.gpu, PLANNER_SEED),
        }
    }

    /// Observe one admission boundary's traffic — plus the measured
    /// execution since the previous boundary (one whole batch in gang
    /// mode, the dwell window of iterations in streaming mode), which
    /// closes the loop on mispredicted plans — and return the
    /// (prefill, decode) plans the controller lands on, with its
    /// decision so the caller can count weight-moving switches. The
    /// grid engine executes whatever the planner picked — hybrids
    /// included.
    pub(crate) fn select(
        &mut self,
        cfg: &AdaptiveServing,
        samples: &[TrafficSample],
        measured: Option<MeasuredLatency>,
    ) -> Result<(ShardPlan, ShardPlan, SwitchDecision)> {
        let planner = HapPlanner::with_latency(&cfg.model, &cfg.node, self.latency.clone());
        let (plan, decision) =
            self.control.step(&planner, samples.iter().copied(), None, measured)?;
        Ok((
            ShardPlan::new(plan.attn, plan.expert_prefill),
            ShardPlan::new(plan.attn, plan.expert_decode),
            decision,
        ))
    }
}

/// A request occupying one batch slot.
struct Slot {
    req: Request,
    tokens: Vec<i32>,
    last: i32,
    remaining: usize,
    ttft: f64,
    /// Chunked-prefill state: the padded prompt row and the chunk
    /// cursor (tokens prefilled so far). `Some` while the slot is in
    /// the *Prefilling* phase — it takes no decode steps, and its
    /// first token (and TTFT) lands only when the final chunk's logits
    /// do. `None` once decoding.
    prefill: Option<(Vec<i32>, usize)>,
}

impl Slot {
    /// Whether this slot takes decode steps (prefill fully landed).
    fn decoding(&self) -> bool {
        self.prefill.is_none()
    }
}

/// The scheduler core, separated from executor ownership so the compat
/// wrappers ([`serve_with`]) can drive a caller-owned executor while
/// [`Engine`] owns its own.
struct Session {
    config: ServeConfig,
    scheduling: Scheduling,
    meta: TinyModelMeta,
    batcher: Batcher,
    router: Router,
    /// Joiners already taken from the router when an attention-layout
    /// switch was decided: they wait here (in admission order) while
    /// the running set drains, and are admitted first under the new
    /// session.
    backlog: Vec<Request>,
    slots: Vec<Option<Slot>>,
    /// Every completed response, in retirement order (the report).
    responses: Vec<Response>,
    /// Delivery watermark: `responses[..delivered]` have been handed
    /// out by `drain`; the tail is pending delivery. An index instead
    /// of a second Vec so tokens are stored once and the retire path
    /// never deep-clones.
    delivered: usize,
    metrics: Metrics,
    adapt: Option<AdaptState>,
    /// Gang mode: previous batch's measured execution for the adapt
    /// loop (wall seconds + tokens generated).
    last_measured: Option<MeasuredLatency>,
    /// Streaming: wall seconds of model execution (prefill chunks +
    /// decode steps) accumulated under the active plan since the last
    /// adapt consult — the per-plan dwell accumulator...
    dwell_seconds: f64,
    /// ...and the tokens generated in that window. Together they are
    /// the `MeasuredLatency` handed to the adapt loop at the next
    /// admission boundary (then reset), closing the measured-latency
    /// feedback at iteration granularity.
    dwell_tokens: usize,
    /// Set by [`Self::request_plans`]: the session's plan was forced
    /// out from under the controller, so the next consult's dwell
    /// window ran under a plan the controller does not consider
    /// active — withhold it from the mispredict EWMA (and drop it)
    /// instead of attributing it to the wrong plan.
    suppress_measured: bool,
    /// Streaming: the session's resident (prefill, decode) plans.
    active: Option<(ShardPlan, ShardPlan)>,
    /// Streaming: an attention-layout switch waiting for the running
    /// set to drain.
    pending: Option<(ShardPlan, ShardPlan)>,
    prefill_time: f64,
    decode_time: f64,
    stats0: ExecStats,
    run_start: Instant,
}

impl Session {
    fn new(exec: &ModelExecutor, config: ServeConfig, scheduling: Scheduling) -> Session {
        let meta = exec.meta().clone();
        let batcher = Batcher::new(meta.batch, meta.prefill_len, meta.max_len - meta.prefill_len);
        let router = Router::new(config.queue_capacity, config.policy);
        let adapt = config.adaptive.as_ref().map(AdaptState::new);
        Session {
            slots: (0..meta.batch).map(|_| None).collect(),
            backlog: Vec::new(),
            responses: Vec::new(),
            delivered: 0,
            metrics: Metrics::new(),
            adapt,
            last_measured: None,
            dwell_seconds: 0.0,
            dwell_tokens: 0,
            suppress_measured: false,
            active: None,
            pending: None,
            prefill_time: 0.0,
            decode_time: 0.0,
            stats0: exec.stats(),
            run_start: Instant::now(),
            config,
            scheduling,
            meta,
            batcher,
            router,
        }
    }

    /// Enqueue a request. A full queue backpressures by running
    /// scheduler iterations until a slot frees (a full queue is never
    /// empty, so every iteration makes progress) — the old API's hard
    /// `bail!` on overflow is gone.
    fn submit(&mut self, exec: &mut ModelExecutor, req: Request) -> Result<RequestId> {
        if self.router.capacity == 0 {
            anyhow::bail!("queue capacity is 0 — no request can ever be admitted");
        }
        let id = req.id;
        let mut req = req;
        loop {
            // Wait for queue room BEFORE attempting admission: engine
            // backpressure is a drain, not a rejection, so the waiting
            // iterations leave the router's `rejected` counter alone
            // (it keeps counting only true rejections seen by direct
            // router users).
            if self.router.pending() < self.router.capacity {
                match self.router.try_submit(req) {
                    None => return Ok(id),
                    Some(back) => req = back,
                }
            }
            self.step(exec)?;
        }
    }

    fn step(&mut self, exec: &mut ModelExecutor) -> Result<StepOutcome> {
        match self.scheduling {
            Scheduling::Gang => self.gang_step(exec),
            Scheduling::Streaming => self.stream_step(exec),
        }
    }

    /// One gang iteration: pack a whole batch and run it to completion
    /// (the legacy `serve_on` loop body, preserved for the compat
    /// wrappers, the PJRT backend, and baseline comparisons).
    fn gang_step(&mut self, exec: &mut ModelExecutor) -> Result<StepOutcome> {
        let mut out = StepOutcome::default();
        if self.router.is_empty() {
            return Ok(out);
        }
        let batch = self.batcher.pack(self.router.take(self.meta.batch));
        // Per-batch strategy selection (adaptive) or the fixed plan.
        let (prefill_plan, decode_plan) = match (&mut self.adapt, &self.config.adaptive) {
            (Some(state), Some(cfg)) => {
                let samples: Vec<TrafficSample> = batch
                    .requests
                    .iter()
                    .map(|req| TrafficSample {
                        prompt: req.prompt.len(),
                        generate: req.max_new_tokens,
                        batch: batch.requests.len(),
                    })
                    .collect();
                let (p, d, decision) = state.select(cfg, &samples, self.last_measured)?;
                if matches!(decision, SwitchDecision::Switch { .. }) {
                    self.metrics.replans += 1;
                    out.switched = true;
                }
                (p, d)
            }
            _ => (
                ShardPlan::new(self.config.attn, self.config.expert_prefill),
                ShardPlan::new(self.config.attn, self.config.expert_decode),
            ),
        };
        // Declare the batch's plans: evicts stale layouts, materializes
        // missing shards — the measured resharding work of a switch.
        exec.begin_batch(&prefill_plan, &decode_plan)?;

        // ---- Prefill.
        let t0 = Instant::now();
        let logits = exec.prefill(&batch.tokens, &prefill_plan)?;
        let batch_prefill = t0.elapsed().as_secs_f64();
        self.prefill_time += batch_prefill;
        self.metrics.batches_prefilled += 1;
        if prefill_plan.expert != decode_plan.expert {
            self.metrics.transitions += 1;
        }

        let first = argmax_rows(&logits);
        let first_time = Instant::now();
        let mut generated: Vec<Vec<i32>> =
            (0..batch.live()).map(|slot| vec![first[slot] as i32]).collect();
        let mut last: Vec<i32> = first.iter().map(|&t| t as i32).collect();
        let mut remaining = batch.remaining.clone();
        for r in remaining.iter_mut().take(batch.live()) {
            *r = r.saturating_sub(1);
        }

        // ---- Decode until every live slot finishes (the convoy).
        let t0 = Instant::now();
        while remaining.iter().take(batch.live()).any(|&r| r > 0) {
            let active = remaining.iter().take(batch.live()).filter(|&&r| r > 0).count();
            let logits = exec.decode_step(&last, &decode_plan)?;
            self.metrics.decode_steps += 1;
            self.metrics.observe_occupancy(active, self.meta.batch);
            // Count live slots, not iterations, so gang and streaming
            // report the same quantity (slot decode steps).
            out.decoded += active;
            let next = argmax_rows(&logits);
            for slot in 0..batch.live() {
                if remaining[slot] > 0 {
                    generated[slot].push(next[slot] as i32);
                    remaining[slot] -= 1;
                }
            }
            last = next.iter().map(|&t| t as i32).collect();
        }
        let batch_decode = t0.elapsed().as_secs_f64();
        self.decode_time += batch_decode;
        // Feed the measured execution of this batch — seconds and the
        // tokens it generated, so the adapt loop can normalize to
        // seconds-per-token — into the next adaptation step (demotes
        // consistently mispredicted plans).
        let batch_tokens: usize = generated.iter().map(|g| g.len()).sum();
        self.last_measured =
            Some(MeasuredLatency::new(batch_prefill + batch_decode, batch_tokens));

        // ---- Retire the whole batch.
        let now = Instant::now();
        for (slot, req) in batch.requests.iter().enumerate() {
            let latency = now.duration_since(req.arrived).as_secs_f64();
            let ttft = first_time.duration_since(req.arrived).as_secs_f64();
            self.metrics.observe_request(latency, ttft, generated[slot].len());
            self.responses.push(Response {
                id: req.id,
                tokens: generated[slot].clone(),
                latency,
                ttft,
            });
        }
        out.admitted = batch.live();
        out.retired = batch.live();
        out.queued = self.router.pending();
        Ok(out)
    }

    /// Drop the accumulated dwell window: it measured a plan that is
    /// no longer (or, when a consult just consumed it, no further) the
    /// subject of the next measured hand-off. Every plan-switch path
    /// and the consult itself funnel through this one reset so a
    /// window can never straddle two plans.
    fn reset_dwell(&mut self) {
        self.dwell_seconds = 0.0;
        self.dwell_tokens = 0;
    }

    /// The prefill chunk this slot gets this iteration: at most
    /// `config.prefill_chunk` tokens of the `row_len`-token padded
    /// prompt (0 = unchunked, the whole remaining prompt at once).
    fn chunk_len(&self, row_len: usize, cursor: usize) -> usize {
        let chunk = if self.config.prefill_chunk == 0 {
            row_len
        } else {
            self.config.prefill_chunk
        };
        chunk.min(row_len - cursor)
    }

    /// Run ONE prefill chunk for the Prefilling slot at `idx` — its
    /// first right after admission, or the next at its cursor — and
    /// handle completion: the final chunk's logits are the same
    /// first-token logits a one-shot prefill of the row yields
    /// (chunking is bit-exact), so the first token and TTFT land
    /// there, and a request whose budget is already satisfied retires
    /// on the spot without a decode iteration. The ONE chunk-execution
    /// path shared by the advance loop and the admission step.
    /// Returns whether the slot is still occupied afterwards.
    fn advance_chunk(
        &mut self,
        exec: &mut ModelExecutor,
        idx: usize,
        out: &mut StepOutcome,
    ) -> Result<bool> {
        let (prefill_plan, _) = self.active.expect("prefilling slot implies a session");
        // Pull the chunk state out to keep the slot borrow short.
        let (row, cursor) = {
            let slot = self.slots[idx].as_mut().expect("advancing an empty slot");
            slot.prefill.take().expect("slot is not prefilling")
        };
        let c = self.chunk_len(row.len(), cursor);
        let t0 = Instant::now();
        let res = exec.prefill_slot(idx, &row[cursor..cursor + c], &prefill_plan);
        let dt = t0.elapsed().as_secs_f64();
        self.prefill_time += dt;
        self.dwell_seconds += dt;
        let logits = match res {
            Ok(logits) => logits,
            Err(e) => {
                // Put the cursor back: without it the slot would read
                // as "decoding" while its KV is only partially written
                // — unretirable if the caller treats the step error as
                // transient and keeps driving.
                self.slots[idx].as_mut().expect("still occupied").prefill =
                    Some((row, cursor));
                return Err(e);
            }
        };
        self.metrics.prefill_chunks += 1;
        let done = cursor + c == row.len();
        let retire_now = {
            let slot = self.slots[idx].as_mut().expect("still occupied");
            if done {
                let first = argmax_rows(&logits)[0] as i32;
                slot.tokens.push(first);
                slot.last = first;
                slot.ttft = slot.req.arrived.elapsed().as_secs_f64();
                // Saturating like the gang path: a zero-budget request
                // still yields its one prefill token.
                slot.remaining = slot.remaining.saturating_sub(1);
                slot.remaining == 0
            } else {
                slot.prefill = Some((row, cursor + c));
                false
            }
        };
        if done {
            self.dwell_tokens += 1;
        }
        if retire_now {
            self.retire_slot(exec, idx, out)?;
            return Ok(false);
        }
        Ok(true)
    }

    /// Retire the request occupying `slots[idx]`: free its executor
    /// slot (zeroing its KV rows), record request metrics, and queue
    /// the response for delivery. The one retirement path shared by
    /// the finished-decode, final-chunk, and single-token cases.
    fn retire_slot(
        &mut self,
        exec: &mut ModelExecutor,
        idx: usize,
        out: &mut StepOutcome,
    ) -> Result<()> {
        let slot = self.slots[idx].take().expect("retiring an empty slot");
        exec.release_slot(idx)?;
        let latency = slot.req.arrived.elapsed().as_secs_f64();
        self.metrics.observe_request(latency, slot.ttft, slot.tokens.len());
        self.responses.push(Response {
            id: slot.req.id,
            tokens: slot.tokens,
            latency,
            ttft: slot.ttft,
        });
        out.retired += 1;
        Ok(())
    }

    /// One streaming iteration: retire → (apply drained switch) →
    /// advance in-flight chunked prefills → admit + first prefill
    /// chunk → one decode step at per-slot positions.
    fn stream_step(&mut self, exec: &mut ModelExecutor) -> Result<StepOutcome> {
        let mut out = StepOutcome::default();
        let b = self.meta.batch;

        // ---- 1. Retire finished sequences, freeing KV + batch slots.
        // Only decoding slots: a mid-prefill slot with a zero-token
        // budget still needs its final chunk to produce its one token.
        for idx in 0..self.slots.len() {
            let done = self.slots[idx]
                .as_ref()
                .map_or(false, |s| s.remaining == 0 && s.decoding());
            if done {
                self.retire_slot(exec, idx, &mut out)?;
            }
        }
        let mut running = self.slots.iter().filter(|s| s.is_some()).count();

        // ---- 2. An attention-layout switch waited for this safe
        // point: the running set is drained, so the KV sharding can
        // change. Re-begin the session and resume admission.
        if running == 0 {
            if let Some((p, d)) = self.pending.take() {
                exec.begin_session(&p, &d)?;
                self.active = Some((p, d));
                // The dwell window measured the outgoing plan; the
                // consult that decided this switch already consumed it.
                self.reset_dwell();
                out.switched = true;
            }
        }

        // ---- 3. Advance in-flight chunked prefills: each Prefilling
        // slot gets at most one `prefill_chunk`-token chunk per
        // iteration, so a long-prompt joiner never stalls its peers'
        // decode for a whole prompt. The final chunk's logits are the
        // prompt's first-token logits — the first token (and TTFT)
        // land here. This runs even while an attention-layout switch is
        // pending: prefilling slots are part of the running set that
        // must drain before the switch can apply.
        for idx in 0..self.slots.len() {
            let prefilling =
                self.slots[idx].as_ref().map_or(false, |s| s.prefill.is_some());
            if !prefilling {
                continue;
            }
            if !self.advance_chunk(exec, idx, &mut out)? {
                running -= 1;
            }
        }

        // ---- 4. Admission boundary: take the joiners, consult the
        // adapt loop on that actual traffic (handing it the measured
        // dwell window since the previous consult), apply safe
        // switches, then run each joiner's FIRST prefill chunk while
        // its peers' KV stays live. Joiners held back by an
        // attention-layout switch wait in the backlog and are admitted
        // first once the drain completes.
        if self.pending.is_none() && running < b {
            let free = b - running;
            let mut joiners = std::mem::take(&mut self.backlog);
            // Joiners re-surfacing from the backlog were already
            // observed by the consult that parked them — only freshly
            // dequeued requests become new traffic samples, so a
            // switch-drain never double-counts them in the window.
            let backlog_n = joiners.len();
            if joiners.len() < free && !self.router.is_empty() {
                joiners.extend(self.router.take(free - joiners.len()));
            }
            if !joiners.is_empty() {
                let desired = match (&mut self.adapt, &self.config.adaptive) {
                    (Some(state), Some(cfg)) => {
                        let concurrency = (running + joiners.len()).min(b);
                        let samples: Vec<TrafficSample> = joiners[backlog_n..]
                            .iter()
                            .map(|r| TrafficSample {
                                prompt: r.prompt.len(),
                                generate: r.max_new_tokens,
                                batch: concurrency,
                            })
                            .collect();
                        // Measured-latency feedback at iteration
                        // granularity: the dwell window (prefill-chunk
                        // + decode seconds, and the tokens they
                        // generated) since the previous consult, all
                        // run under the current active plan. The adapt
                        // loop normalizes it to seconds-per-token, so
                        // streaming and gang observations feed the
                        // same mispredict EWMA.
                        let measured = if self.suppress_measured || self.dwell_tokens == 0 {
                            None
                        } else {
                            Some(MeasuredLatency::new(self.dwell_seconds, self.dwell_tokens))
                        };
                        let (p, d, decision) = state.select(cfg, &samples, measured)?;
                        // Reset when the window was consumed — or when
                        // it was suppressed (it ran under a forced
                        // plan the controller never adopted, so it is
                        // dropped, not carried). A token-less window
                        // (only prefill chunks ran) keeps accumulating
                        // its seconds toward the next consult instead
                        // of silently losing the plan's measured cost.
                        if measured.is_some() || self.suppress_measured {
                            self.reset_dwell();
                            self.suppress_measured = false;
                        }
                        if matches!(decision, SwitchDecision::Switch { .. }) {
                            self.metrics.replans += 1;
                        }
                        Some((p, d))
                    }
                    _ => None,
                };
                let fallback = (
                    ShardPlan::new(self.config.attn, self.config.expert_prefill),
                    ShardPlan::new(self.config.attn, self.config.expert_decode),
                );
                let want = desired.unwrap_or_else(|| self.active.unwrap_or(fallback));
                match self.active {
                    None => {
                        // First admission starts the session directly under
                        // the selected plans — no wasted uploads.
                        exec.begin_session(&want.0, &want.1)?;
                        self.active = Some(want);
                    }
                    Some(cur) if cur != want => {
                        if cur.0.attn == want.0.attn {
                            // Expert-only reshard: per-slot KV is untouched,
                            // so in-flight decodes continue under the new
                            // expert layout after the measured weight move.
                            exec.begin_batch(&want.0, &want.1)?;
                            self.active = Some(want);
                            // Any dwell the consult withheld (token-less
                            // window) measured the outgoing plan — drop
                            // it rather than attribute it to this one.
                            self.reset_dwell();
                            out.switched = true;
                        } else if running == 0 {
                            // The running set is already empty: the KV
                            // sharding can change right now, so apply the
                            // attention-layout switch immediately instead
                            // of burning a dead iteration on the
                            // pending/backlog detour.
                            exec.begin_session(&want.0, &want.1)?;
                            self.active = Some(want);
                            self.reset_dwell();
                            out.switched = true;
                        } else {
                            // KV sharding would change under live slots:
                            // stop admitting and drain in-flight decodes
                            // to the safe point.
                            self.pending = Some(want);
                        }
                    }
                    _ => {}
                }
                if self.pending.is_some() {
                    self.backlog = joiners;
                } else {
                    let (prefill_plan, decode_plan) =
                        self.active.expect("session started above");
                    for req in joiners {
                        let slot = exec.claim_slot().ok_or_else(|| {
                            anyhow::anyhow!("no free slot for admitted request")
                        })?;
                        debug_assert!(self.slots[slot].is_none(), "slot maps diverged");
                        let (row, budget) = self.batcher.pack_one(&req);
                        self.metrics.batches_prefilled += 1;
                        if prefill_plan.expert != decode_plan.expert {
                            self.metrics.transitions += 1;
                        }
                        out.admitted += 1;
                        // Every joiner enters in the Prefilling phase at
                        // cursor 0 and runs its first chunk right away;
                        // `advance_chunk` promotes it to Decoding (or
                        // retires a single-token request) if that chunk
                        // already completes the prompt — the unchunked
                        // configuration in one step.
                        self.slots[slot] = Some(Slot {
                            req,
                            tokens: Vec::new(),
                            last: 0,
                            remaining: budget,
                            ttft: 0.0,
                            prefill: Some((row, 0)),
                        });
                        if self.advance_chunk(exec, slot, &mut out)? {
                            running += 1;
                        }
                    }
                }
            }
        }

        // ---- 5. One decode iteration for the decoding slots. Slots
        // still chunk-prefilling ride this iteration inert (the
        // executor skips their KV and position).
        let decoding = self.slots.iter().flatten().filter(|s| s.decoding()).count();
        if decoding > 0 {
            let (_, decode_plan) = self.active.expect("decoding implies a session");
            let mut last = vec![0i32; b];
            for (i, s) in self.slots.iter().enumerate() {
                if let Some(slot) = s {
                    if slot.decoding() {
                        last[i] = slot.last;
                    }
                }
            }
            let t0 = Instant::now();
            let logits = exec.decode_slots(&last, &decode_plan)?;
            let dt = t0.elapsed().as_secs_f64();
            self.decode_time += dt;
            self.dwell_seconds += dt;
            self.metrics.decode_steps += 1;
            self.metrics.observe_occupancy(decoding, b);
            let next = argmax_rows(&logits);
            for (i, s) in self.slots.iter_mut().enumerate() {
                if let Some(slot) = s {
                    if !slot.decoding() {
                        continue;
                    }
                    if slot.remaining > 0 {
                        slot.tokens.push(next[i] as i32);
                        slot.remaining -= 1;
                    }
                    slot.last = next[i] as i32;
                }
            }
            self.dwell_tokens += decoding;
            out.decoded = decoding;
        }

        out.running = self.slots.iter().filter(|s| s.is_some()).count();
        out.queued = self.router.pending() + self.backlog.len();
        Ok(out)
    }

    /// Request a plan change (fixed-plan engines; adaptive engines
    /// re-select at every admission boundary anyway). Applied at the
    /// next safe point: immediately for expert-only switches, after the
    /// running set drains for attention-layout changes, at the next
    /// batch for the gang scheduler.
    fn request_plans(
        &mut self,
        exec: &mut ModelExecutor,
        prefill: ShardPlan,
        decode: ShardPlan,
    ) -> Result<()> {
        exec.validate(&prefill)?;
        exec.validate(&decode)?;
        if prefill.attn != decode.attn {
            anyhow::bail!(
                "attention strategy must match across stages ({} vs {})",
                prefill.attn,
                decode.attn
            );
        }
        // Keep the fixed fallback in sync so a not-yet-started session
        // (or the gang scheduler's next batch) picks the new plans up.
        self.config.attn = prefill.attn;
        self.config.expert_prefill = prefill.expert;
        self.config.expert_decode = decode.expert;
        // The latest request supersedes any switch still waiting on a
        // drain — otherwise a stale pending plan would pop at the next
        // safe point and silently revert this one. The drain-wait
        // branch below re-queues when these plans themselves must wait.
        let cancelled = self.pending.take().is_some();
        match self.active {
            Some(cur) if cur == (prefill, decode) => {
                if cancelled {
                    // A controller-decided switch was cancelled while
                    // the controller already adopted its plan: the
                    // session keeps executing the old layout, so the
                    // dwell window must not feed the (never-applied)
                    // adopted plan's mispredict EWMA.
                    self.reset_dwell();
                    self.suppress_measured = true;
                }
            }
            Some(cur) if cur.0.attn == prefill.attn => {
                exec.begin_batch(&prefill, &decode)?;
                self.active = Some((prefill, decode));
                // The dwell window measured the outgoing plan; don't
                // let it be attributed to the new one. And because the
                // session plan was forced out from under an adaptive
                // controller, the NEXT window (run under the forced
                // plan) must not feed the controller's still-active
                // plan's EWMA either.
                self.reset_dwell();
                self.suppress_measured = true;
            }
            Some(_) if self.slots.iter().all(|s| s.is_none()) => {
                // Attention-layout switch with the running set already
                // empty: the KV sharding can change right now, so
                // re-begin the session instead of burning an iteration
                // on the pending/drain detour.
                exec.begin_session(&prefill, &decode)?;
                self.active = Some((prefill, decode));
                self.reset_dwell();
                self.suppress_measured = true;
            }
            Some(_) => {
                self.pending = Some((prefill, decode));
                self.suppress_measured = true;
            }
            None => {}
        }
        Ok(())
    }

    fn status(&self, id: RequestId) -> RequestStatus {
        if let Some(resp) = self.responses.iter().rev().find(|r| r.id == id) {
            return RequestStatus::Finished(resp.clone());
        }
        for s in self.slots.iter().flatten() {
            if s.req.id == id {
                return RequestStatus::Running { tokens: s.tokens.clone() };
            }
        }
        if self.router.contains(id) || self.backlog.iter().any(|r| r.id == id) {
            return RequestStatus::Queued;
        }
        RequestStatus::Unknown
    }

    fn idle(&self) -> bool {
        self.router.is_empty()
            && self.backlog.is_empty()
            && self.slots.iter().all(|s| s.is_none())
    }

    fn run_to_idle(&mut self, exec: &mut ModelExecutor) -> Result<()> {
        while !self.idle() {
            self.step(exec)?;
        }
        Ok(())
    }

    fn take_undelivered(&mut self) -> Vec<Response> {
        let out = self.responses[self.delivered..].to_vec();
        self.delivered = self.responses.len();
        out
    }

    /// Close the books: wall time, executor upload/reshard deltas, plan
    /// cache persistence — the same accounting the old loop did.
    fn finish(mut self, exec: &ModelExecutor) -> Result<ServeReport> {
        self.metrics.wall_time = self.run_start.elapsed().as_secs_f64();
        let stats = exec.stats();
        self.metrics.weight_uploads = stats.materializations - self.stats0.materializations;
        self.metrics.reshards = stats.reshards - self.stats0.reshards;
        self.metrics.reshard_time = stats.reshard_seconds - self.stats0.reshard_seconds;
        if let (Some(state), Some(cfg)) = (&self.adapt, &self.config.adaptive) {
            if let Some(path) = &cfg.plan_cache {
                if let Err(e) = state.control.cache.save(path) {
                    eprintln!("could not save plan cache {}: {e:#}", path.display());
                }
            }
        }
        Ok(ServeReport {
            metrics: self.metrics,
            responses: self.responses,
            prefill_time: self.prefill_time,
            decode_time: self.decode_time,
        })
    }
}

/// Serve a whole workload on a **caller-owned** executor under the
/// given scheduling mode, to completion. This is the engine core the
/// deprecated [`super::serve_on`]/[`super::serve_workload`] wrappers
/// call with [`Scheduling::Gang`]; pass [`Scheduling::Streaming`] to
/// run continuous batching over an executor you keep across runs.
pub fn serve_with(
    exec: &mut ModelExecutor,
    config: &ServeConfig,
    scheduling: Scheduling,
    workload: Vec<Request>,
) -> Result<ServeReport> {
    let mut session = Session::new(exec, config.clone(), scheduling);
    for req in workload {
        session.submit(exec, req)?;
    }
    session.run_to_idle(exec)?;
    session.finish(exec)
}

/// Typed constructor for [`Engine`]: serving config (fixed plan or
/// adaptive policy, router policy, queue capacity) plus the scheduling
/// mode, then a backend.
pub struct EngineBuilder {
    config: ServeConfig,
    scheduling: Scheduling,
}

impl EngineBuilder {
    /// Replace the whole serving config.
    pub fn config(mut self, config: ServeConfig) -> EngineBuilder {
        self.config = config;
        self
    }

    /// Scheduling mode (default: streaming).
    pub fn scheduling(mut self, scheduling: Scheduling) -> EngineBuilder {
        self.scheduling = scheduling;
        self
    }

    /// Router queue capacity.
    pub fn queue_capacity(mut self, capacity: usize) -> EngineBuilder {
        self.config.queue_capacity = capacity;
        self
    }

    /// Router queue discipline.
    pub fn policy(mut self, policy: super::router::RouterPolicy) -> EngineBuilder {
        self.config.policy = policy;
        self
    }

    /// Max prompt tokens prefilled per joiner per streaming iteration
    /// (0 = unchunked). See [`ServeConfig::prefill_chunk`].
    pub fn prefill_chunk(mut self, tokens: usize) -> EngineBuilder {
        self.config.prefill_chunk = tokens;
        self
    }

    /// Online-adaptive plan selection (consulted per admission
    /// boundary in streaming mode, per batch in gang mode).
    pub fn adaptive(mut self, adaptive: AdaptiveServing) -> EngineBuilder {
        self.config.adaptive = Some(adaptive);
        self
    }

    /// Artifact-free engine on the host grid kernels.
    pub fn build_host(self, weights: WeightStore) -> Engine<'static> {
        self.build_host_with_mode(weights, EngineMode::Parallel)
    }

    /// Host engine with an explicit per-device scheduling mode (the
    /// sequential mode is the bit-equivalence reference path).
    pub fn build_host_with_mode(self, weights: WeightStore, mode: EngineMode) -> Engine<'static> {
        let exec = ModelExecutor::host_with_mode(weights, mode);
        let session = Session::new(&exec, self.config, self.scheduling);
        Engine { exec, session }
    }

    /// PJRT-artifact engine. Gang scheduling only: the fixed-shape
    /// artifacts take one scalar decode position per batch, which
    /// cannot express the streaming engine's per-slot offsets.
    pub fn build_pjrt(self, rt: &PjrtRuntime) -> Result<Engine<'_>> {
        if self.scheduling == Scheduling::Streaming {
            anyhow::bail!(
                "streaming scheduling is host-backend only: the fixed-shape PJRT artifacts \
                 pin one scalar decode position per batch (use --engine gang, or the host \
                 backend)"
            );
        }
        let exec = ModelExecutor::new(rt)?;
        let session = Session::new(&exec, self.config, self.scheduling);
        Ok(Engine { exec, session })
    }
}

/// The long-lived serving engine: owns the [`ModelExecutor`] (weight
/// shards and per-slot KV stay device-resident across requests) and the
/// iteration scheduler. See the module docs for the step anatomy.
pub struct Engine<'rt> {
    exec: ModelExecutor<'rt>,
    session: Session,
}

impl<'rt> Engine<'rt> {
    /// Start building an engine from a serving config.
    pub fn builder(config: ServeConfig) -> EngineBuilder {
        EngineBuilder { config, scheduling: Scheduling::Streaming }
    }

    /// Enqueue a request (backpressures by running scheduler iterations
    /// when the queue is full — never drops or aborts).
    pub fn submit(&mut self, req: Request) -> Result<RequestId> {
        self.session.submit(&mut self.exec, req)
    }

    /// Run ONE scheduler iteration (retire → admit/prefill → decode).
    /// Non-blocking: returns immediately with what it did; an idle
    /// outcome means there is nothing left to schedule.
    pub fn step(&mut self) -> Result<StepOutcome> {
        self.session.step(&mut self.exec)
    }

    /// Non-blocking progress query for a submitted request.
    pub fn poll(&self, id: RequestId) -> RequestStatus {
        self.session.status(id)
    }

    /// Collect the responses finished since the last `drain` —
    /// non-blocking streaming delivery, no scheduler work is run.
    /// Responses handed out here are not repeated by later `drain`
    /// calls; `shutdown`'s report still carries everything.
    pub fn drain(&mut self) -> Vec<Response> {
        self.session.take_undelivered()
    }

    /// Run scheduler iterations until all submitted work completes
    /// (the blocking companion to `drain`; `shutdown` does this and
    /// also closes the books).
    pub fn run_to_completion(&mut self) -> Result<()> {
        self.session.run_to_idle(&mut self.exec)
    }

    /// Request a (prefill, decode) plan switch, applied at the next
    /// safe point (see [`Session::request_plans`] semantics in the
    /// module docs). Intended for fixed-plan engines; adaptive engines
    /// re-select at every admission boundary.
    pub fn force_plans(&mut self, prefill: ShardPlan, decode: ShardPlan) -> Result<()> {
        self.session.request_plans(&mut self.exec, prefill, decode)
    }

    /// Metrics accumulated so far (finalized by `shutdown`).
    pub fn metrics(&self) -> &Metrics {
        &self.session.metrics
    }

    /// The adaptation loop, when this engine was built with an
    /// adaptive config — read-only access to the traffic window, plan
    /// cache, and controller (e.g. its measured mispredict EWMAs).
    pub fn adapt(&self) -> Option<&AdaptLoop> {
        self.session.adapt.as_ref().map(|state| &state.control)
    }

    /// The underlying executor (shard/upload accounting lives here).
    pub fn executor(&self) -> &ModelExecutor<'rt> {
        &self.exec
    }

    /// Finish all submitted work and return the run report — the same
    /// [`ServeReport`] the deprecated free functions produced.
    pub fn shutdown(mut self) -> Result<ServeReport> {
        self.session.run_to_idle(&mut self.exec)?;
        self.session.finish(&self.exec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DeviceGrid;

    #[test]
    fn adaptive_selection_returns_native_grid_plans() {
        // The adaptation loop needs no runtime: feed it an admission
        // boundary's samples and check it lands on plans that lower to
        // well-formed device grids at the node's device count — the
        // planner's pick is executed natively (hybrid EP×TP included),
        // never projected onto a pure layout.
        let config = ServeConfig::adaptive(4);
        let acfg = config.adaptive.as_ref().unwrap();
        let mut state = AdaptState::new(acfg);
        let samples: Vec<TrafficSample> =
            (0..4).map(|_| TrafficSample { prompt: 24, generate: 16, batch: 4 }).collect();
        let (pre, dec, decision) = state.select(acfg, &samples, None).unwrap();
        assert_eq!(decision, SwitchDecision::Adopt);
        assert_eq!(pre.attn, dec.attn, "attention is pinned across stages");
        for plan in [&pre, &dec] {
            assert_eq!(plan.devices(), 4);
            let grid = DeviceGrid::lower(plan).unwrap();
            let m = acfg.model.clone();
            grid.check_dims(m.q_heads, m.kv_heads, m.num_experts, m.moe_inter_size, 4)
                .unwrap();
        }
        assert!(state.control.controller.active().is_some());
        // A second identical boundary is a cache hit, not a re-solve.
        state.select(acfg, &samples, None).unwrap();
        assert_eq!(state.control.cache.hits, 1);
        assert_eq!(state.control.cache.misses, 1);
    }

    #[test]
    fn streaming_engine_smoke_submit_step_poll_drain() {
        let m = TinyModelMeta::host_demo();
        let weights = WeightStore::synthetic(&m, 5);
        let mut engine = Engine::builder(ServeConfig::tp(4))
            .build_host_with_mode(weights, EngineMode::Sequential);
        let id0 = engine.submit(Request::new(0, vec![1, 2, 3], 3)).unwrap();
        let id1 = engine.submit(Request::new(1, vec![4, 5], 5)).unwrap();
        assert!(matches!(engine.poll(id0), RequestStatus::Queued));
        let out = engine.step().unwrap();
        assert_eq!(out.admitted, 2);
        assert_eq!(out.running, 2);
        assert_eq!(out.decoded, 2);
        match engine.poll(id0) {
            RequestStatus::Running { tokens } => assert_eq!(tokens.len(), 2),
            other => panic!("expected running, got {other:?}"),
        }
        // id0 needs 3 tokens: 1 from prefill + 2 decodes, then a retire
        // step; id1 runs longer.
        engine.run_to_completion().unwrap();
        let responses = engine.drain();
        assert_eq!(responses.len(), 2);
        assert!(matches!(engine.poll(id0), RequestStatus::Finished(_)));
        assert!(matches!(engine.poll(id1), RequestStatus::Finished(_)));
        assert!(matches!(engine.poll(99), RequestStatus::Unknown));
        assert!(engine.drain().is_empty(), "drain repeats responses");
        let report = engine.shutdown().unwrap();
        assert_eq!(report.metrics.requests_completed, 2);
        assert_eq!(report.responses.len(), 2, "shutdown report keeps everything");
        let tokens: Vec<usize> = report.responses.iter().map(|r| r.tokens.len()).collect();
        assert!(tokens.contains(&3) && tokens.contains(&5));
    }
}
