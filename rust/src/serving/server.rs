//! The serving loop: drains the router, packs batches, executes
//! prefill + decode on the real PJRT model under a hybrid plan, and
//! reports per-request + aggregate metrics.
//!
//! `serve_workload` is the synchronous core used by the examples,
//! benches, and the `hap serve` CLI; `spawn_server` wraps it in a
//! worker thread with mpsc channels for concurrent submitters.

use super::batcher::Batcher;
use super::metrics::Metrics;
use super::router::{Router, RouterPolicy};
use super::{Request, Response};
use crate::adapt::controller::ControllerConfig;
use crate::adapt::window::TrafficSample;
use crate::adapt::AdaptLoop;
use crate::config::{hardware::NodeConfig, model::MoEModelConfig};
use crate::model::{ModelExecutor, StageStrategy};
use crate::planner::{HapPlanner, PLANNER_SEED};
use crate::runtime::literal::argmax_rows;
use crate::runtime::PjrtRuntime;
use crate::strategy::ExpertStrategy;
use crate::Result;
use std::time::Instant;

/// Online-adaptation settings for the serving loop: the planner inputs
/// (deployment model + platform) and the control-loop tunables.
#[derive(Debug, Clone)]
pub struct AdaptiveServing {
    pub model: MoEModelConfig,
    pub node: NodeConfig,
    pub controller: ControllerConfig,
    pub window_capacity: usize,
}

impl AdaptiveServing {
    /// Replace the deployment model with one derived from a loaded
    /// artifact manifest, so the adaptation economics describe the
    /// model actually being served rather than a preset that may have
    /// drifted from the artifacts on disk.
    pub fn with_manifest_model(
        mut self,
        meta: &crate::runtime::manifest::TinyModelMeta,
    ) -> AdaptiveServing {
        let mut model = MoEModelConfig {
            name: "manifest-model".into(),
            params_b: 0.0,
            layers: meta.layers,
            q_heads: meta.q_heads,
            kv_heads: meta.kv_heads,
            hidden: meta.hidden,
            head_dim: meta.head_dim,
            num_experts: meta.num_experts,
            top_k: meta.top_k,
            shared_experts: 0,
            moe_inter_size: meta.inter,
            shared_inter_size: 0,
            vocab: meta.vocab,
            dtype_bytes: 4, // the CPU PJRT artifacts run f32
        };
        model.params_b = model.weight_bytes() as f64 / model.dtype_bytes as f64 / 1e9;
        self.model = model;
        self
    }
}

/// Serving configuration: the hybrid plan to execute, or — when
/// `adaptive` is set — the adaptation loop that re-selects it per batch.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub attn_tp: usize,
    pub expert_prefill: ExpertStrategy,
    pub expert_decode: ExpertStrategy,
    pub policy: RouterPolicy,
    pub queue_capacity: usize,
    /// When set, each batch runs window → plan cache → controller and
    /// executes under the controller's active plan; the fixed fields
    /// above only serve as the pre-traffic fallback.
    pub adaptive: Option<AdaptiveServing>,
}

impl ServeConfig {
    /// Static TP-n baseline.
    pub fn tp(n: usize) -> ServeConfig {
        ServeConfig {
            attn_tp: n,
            expert_prefill: ExpertStrategy::new(n, 1),
            expert_decode: ExpertStrategy::new(n, 1),
            policy: RouterPolicy::Fcfs,
            queue_capacity: 1024,
            adaptive: None,
        }
    }

    /// HAP-style phase-specific plan: EP prefill → TP decode.
    pub fn hap_transition(n: usize) -> ServeConfig {
        ServeConfig {
            attn_tp: n,
            expert_prefill: ExpertStrategy::new(1, n),
            expert_decode: ExpertStrategy::new(n, 1),
            policy: RouterPolicy::Fcfs,
            queue_capacity: 1024,
            adaptive: None,
        }
    }

    /// Online-adaptive serving: per-batch strategy selection driven by
    /// the traffic window, plan cache, and switch controller, planned
    /// for the real tiny-MoE deployment on `n` simulated CPU devices.
    /// Override `adaptive.model` / `adaptive.node` to adapt for a
    /// different deployment.
    pub fn adaptive(n: usize) -> ServeConfig {
        let mut config = Self::tp(n);
        config.adaptive = Some(AdaptiveServing {
            model: MoEModelConfig::tiny_moe(),
            node: NodeConfig::cpu_sim(n),
            controller: ControllerConfig::default(),
            window_capacity: 64,
        });
        config
    }

    pub fn has_transition(&self) -> bool {
        self.expert_prefill != self.expert_decode
    }

    pub fn label(&self) -> String {
        if self.adaptive.is_some() {
            format!("adaptive (fallback attn=TP{})", self.attn_tp)
        } else if self.has_transition() {
            format!(
                "attn=TP{} experts={}→{}",
                self.attn_tp,
                self.expert_prefill.label(),
                self.expert_decode.label()
            )
        } else {
            format!("attn=TP{} experts={}", self.attn_tp, self.expert_prefill.label())
        }
    }
}

/// Per-run state of the adaptation loop: the shared [`AdaptLoop`]
/// (the exact implementation the replay acceptance tests validate)
/// plus the platform's latency model, resolved once so the per-batch
/// path never touches the global model-cache lock.
struct AdaptState {
    control: AdaptLoop,
    latency: std::sync::Arc<crate::sim::LatencyModel>,
}

impl AdaptState {
    fn new(cfg: &AdaptiveServing) -> AdaptState {
        AdaptState {
            control: AdaptLoop::new(cfg.controller.clone(), cfg.window_capacity),
            latency: crate::sim::LatencyModel::cached(&cfg.node.gpu, PLANNER_SEED),
        }
    }

    /// Observe one packed batch and return the (prefill, decode)
    /// strategies the controller lands on.
    fn select(
        &mut self,
        cfg: &AdaptiveServing,
        requests: &[Request],
    ) -> Result<(StageStrategy, StageStrategy)> {
        let planner = HapPlanner::with_latency(&cfg.model, &cfg.node, self.latency.clone());
        let samples = requests.iter().map(|req| TrafficSample {
            prompt: req.prompt.len(),
            generate: req.max_new_tokens,
            batch: requests.len(),
        });
        let (plan, _) = self.control.step(&planner, samples, None)?;
        // The demo executor covers pure-TP and pure-EP expert layouts;
        // project hybrid EP×TP picks onto pure EP at the same device
        // count (the simulation stack covers hybrids exactly).
        let executable = |e: crate::strategy::ExpertStrategy| {
            if e.ep > 1 && e.tp > 1 {
                crate::strategy::ExpertStrategy::new(1, e.devices())
            } else {
                e
            }
        };
        Ok((
            StageStrategy { attn_tp: plan.attn.tp, expert: executable(plan.expert_prefill) },
            StageStrategy { attn_tp: plan.attn.tp, expert: executable(plan.expert_decode) },
        ))
    }
}

/// Aggregate results of a serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub metrics: Metrics,
    pub responses: Vec<Response>,
    /// Measured compute split (seconds).
    pub prefill_time: f64,
    pub decode_time: f64,
}

/// Serve a whole workload to completion (synchronous; the unit the
/// worker thread loops over).
pub fn serve_workload(
    rt: &PjrtRuntime,
    config: &ServeConfig,
    workload: Vec<Request>,
) -> Result<ServeReport> {
    let m = &rt.manifest.model;
    let batcher = Batcher::new(m.batch, m.prefill_len, m.max_len - m.prefill_len);
    let mut router = Router::new(config.queue_capacity, config.policy);
    for req in workload {
        if !router.submit(req) {
            anyhow::bail!("router rejected request (queue capacity {})", config.queue_capacity);
        }
    }

    let fixed_prefill = StageStrategy { attn_tp: config.attn_tp, expert: config.expert_prefill };
    let fixed_decode = StageStrategy { attn_tp: config.attn_tp, expert: config.expert_decode };
    let mut adapt = config.adaptive.as_ref().map(AdaptState::new);

    let mut metrics = Metrics::new();
    let mut responses = Vec::new();
    let mut prefill_time = 0.0;
    let mut decode_time = 0.0;
    let run_start = Instant::now();

    while !router.is_empty() {
        let batch = batcher.pack(router.take(m.batch));
        // Per-batch strategy selection (adaptive) or the fixed plan.
        let (prefill_strategy, decode_strategy) = match (&mut adapt, &config.adaptive) {
            (Some(state), Some(cfg)) => {
                let switches_before = state.control.controller.switches;
                let picked = state.select(cfg, &batch.requests)?;
                metrics.replans += state.control.controller.switches - switches_before;
                picked
            }
            _ => (fixed_prefill.clone(), fixed_decode.clone()),
        };
        let mut exec = ModelExecutor::new(rt)?;

        // ---- Prefill.
        let t0 = Instant::now();
        let logits = exec.prefill(&batch.tokens, &prefill_strategy)?;
        prefill_time += t0.elapsed().as_secs_f64();
        metrics.batches_prefilled += 1;
        if prefill_strategy.expert != decode_strategy.expert {
            metrics.transitions += 1;
        }

        let first = argmax_rows(&logits);
        let first_time = Instant::now();
        let mut generated: Vec<Vec<i32>> = (0..batch.live())
            .map(|slot| vec![first[slot] as i32])
            .collect();
        let mut last: Vec<i32> = first.iter().map(|&t| t as i32).collect();
        let mut remaining = batch.remaining.clone();
        for r in remaining.iter_mut().take(batch.live()) {
            *r = r.saturating_sub(1);
        }

        // ---- Decode until every live slot finishes.
        let t0 = Instant::now();
        while remaining.iter().take(batch.live()).any(|&r| r > 0) {
            let logits = exec.decode_step(&last, &decode_strategy)?;
            metrics.decode_steps += 1;
            let next = argmax_rows(&logits);
            for slot in 0..batch.live() {
                if remaining[slot] > 0 {
                    generated[slot].push(next[slot] as i32);
                    remaining[slot] -= 1;
                }
            }
            last = next.iter().map(|&t| t as i32).collect();
        }
        decode_time += t0.elapsed().as_secs_f64();

        // ---- Retire.
        let now = Instant::now();
        for (slot, req) in batch.requests.iter().enumerate() {
            let latency = now.duration_since(req.arrived).as_secs_f64();
            let ttft = first_time.duration_since(req.arrived).as_secs_f64();
            metrics.observe_request(latency, ttft, generated[slot].len());
            responses.push(Response {
                id: req.id,
                tokens: generated[slot].clone(),
                latency,
                ttft,
            });
        }
    }

    metrics.wall_time = run_start.elapsed().as_secs_f64();
    Ok(ServeReport { metrics, responses, prefill_time, decode_time })
}

/// Spawn the server on a worker thread; returns a submission handle.
pub struct ServerHandle {
    tx: std::sync::mpsc::Sender<Request>,
    done_rx: std::sync::mpsc::Receiver<ServeReport>,
}

impl ServerHandle {
    pub fn submit(&self, req: Request) -> Result<()> {
        self.tx
            .send(req)
            .map_err(|_| anyhow::anyhow!("server thread terminated"))
    }

    /// Close the submission channel and wait for the final report.
    pub fn finish(self) -> Result<ServeReport> {
        drop(self.tx);
        self.done_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server thread panicked"))
    }
}

/// Run the server on its own thread, collecting requests until the
/// handle is finished, then serving everything and reporting.
///
/// The PJRT runtime is not `Send` (FFI handles), so the thread owns its
/// own runtime loaded from `artifacts_dir`.
pub fn spawn_server(
    artifacts_dir: std::path::PathBuf,
    config: ServeConfig,
) -> Result<ServerHandle> {
    let (tx, rx) = std::sync::mpsc::channel::<Request>();
    let (done_tx, done_rx) = std::sync::mpsc::channel::<ServeReport>();
    std::thread::spawn(move || {
        let rt = match PjrtRuntime::load(&artifacts_dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("server: failed to load artifacts: {e:#}");
                return;
            }
        };
        let workload: Vec<Request> = rx.iter().collect();
        match serve_workload(&rt, &config, workload) {
            Ok(report) => {
                let _ = done_tx.send(report);
            }
            Err(e) => eprintln!("server: serving failed: {e:#}"),
        }
    });
    Ok(ServerHandle { tx, done_rx })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configs_label_correctly() {
        assert_eq!(ServeConfig::tp(4).label(), "attn=TP4 experts=TP4");
        let h = ServeConfig::hap_transition(4);
        assert!(h.has_transition());
        assert_eq!(h.label(), "attn=TP4 experts=EP4→TP4");
        assert!(ServeConfig::adaptive(4).label().contains("adaptive"));
    }

    #[test]
    fn adaptive_selection_yields_executable_strategies() {
        // The adaptation loop itself needs no PJRT runtime: feed it a
        // batch of requests and check it lands on a plan the demo
        // executor accepts (attn tp 1/2/4; experts pure TP or pure EP).
        let config = ServeConfig::adaptive(4);
        let acfg = config.adaptive.as_ref().unwrap();
        let mut state = AdaptState::new(acfg);
        let reqs: Vec<Request> =
            (0..4).map(|i| Request::new(i, vec![1; 24], 16)).collect();
        let (pre, dec) = state.select(acfg, &reqs).unwrap();
        assert!(matches!(pre.attn_tp, 1 | 2 | 4));
        assert_eq!(pre.attn_tp, dec.attn_tp);
        for e in [&pre.expert, &dec.expert] {
            assert!(e.ep == 1 || e.tp == 1, "non-executable hybrid {}", e.label());
        }
        assert!(state.control.controller.active().is_some());
        // A second identical batch is a cache hit, not a re-solve.
        state.select(acfg, &reqs).unwrap();
        assert_eq!(state.control.cache.hits, 1);
        assert_eq!(state.control.cache.misses, 1);
    }
}
