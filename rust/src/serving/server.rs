//! The serving loop: drains the router, packs batches, executes
//! prefill + decode on the grid engine under a hybrid plan, and reports
//! per-request + aggregate metrics.
//!
//! `serve_on` is the synchronous core over **one long-lived
//! [`ModelExecutor`]**: weight shards stay device-resident across
//! batches, and a plan switch (adaptive serving) triggers measured
//! resharding work inside `ModelExecutor::begin_batch` — so
//! `Metrics.weight_uploads`/`reshards` describe real weight movement,
//! not a per-batch re-upload. `serve_workload` wraps it for the
//! PJRT-artifact path; the host backend (`ModelExecutor::host`) runs
//! the same loop without artifacts. `spawn_server` adds a worker thread
//! with mpsc channels for concurrent submitters.
//!
//! The grid engine executes any plan the strategy search space emits at
//! the node's device count — hybrid EP×TP experts and DP×TP attention
//! included — so adaptive serving runs the planner's picks natively
//! instead of projecting them onto a pure layout.

use super::batcher::Batcher;
use super::metrics::Metrics;
use super::router::{Router, RouterPolicy};
use super::{Request, Response};
use crate::adapt::controller::ControllerConfig;
use crate::adapt::window::TrafficSample;
use crate::adapt::{AdaptLoop, PlanCache};
use crate::config::{hardware::NodeConfig, model::MoEModelConfig};
use crate::model::{ModelExecutor, ShardPlan};
use crate::planner::{HapPlanner, PLANNER_SEED};
use crate::runtime::literal::argmax_rows;
use crate::runtime::PjrtRuntime;
use crate::strategy::{AttnStrategy, ExpertStrategy};
use crate::Result;
use std::time::Instant;

/// Online-adaptation settings for the serving loop: the planner inputs
/// (deployment model + platform) and the control-loop tunables.
#[derive(Debug, Clone)]
pub struct AdaptiveServing {
    pub model: MoEModelConfig,
    pub node: NodeConfig,
    pub controller: ControllerConfig,
    pub window_capacity: usize,
    /// When set, the plan cache is loaded from this path at startup
    /// (ignored on model/platform fingerprint mismatch) and saved back
    /// at the end of the run.
    pub plan_cache: Option<std::path::PathBuf>,
}

impl AdaptiveServing {
    /// Replace the deployment model with one derived from a loaded
    /// artifact manifest, so the adaptation economics describe the
    /// model actually being served rather than a preset that may have
    /// drifted from the artifacts on disk.
    pub fn with_manifest_model(
        mut self,
        meta: &crate::runtime::manifest::TinyModelMeta,
    ) -> AdaptiveServing {
        let mut model = MoEModelConfig {
            name: "manifest-model".into(),
            params_b: 0.0,
            layers: meta.layers,
            q_heads: meta.q_heads,
            kv_heads: meta.kv_heads,
            hidden: meta.hidden,
            head_dim: meta.head_dim,
            num_experts: meta.num_experts,
            top_k: meta.top_k,
            shared_experts: 0,
            moe_inter_size: meta.inter,
            shared_inter_size: 0,
            vocab: meta.vocab,
            dtype_bytes: 4, // the CPU PJRT artifacts run f32
        };
        model.params_b = model.weight_bytes() as f64 / model.dtype_bytes as f64 / 1e9;
        self.model = model;
        self
    }
}

/// Serving configuration: the hybrid plan to execute, or — when
/// `adaptive` is set — the adaptation loop that re-selects it per batch.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub attn: AttnStrategy,
    pub expert_prefill: ExpertStrategy,
    pub expert_decode: ExpertStrategy,
    pub policy: RouterPolicy,
    pub queue_capacity: usize,
    /// When set, each batch runs window → plan cache → controller and
    /// executes under the controller's active plan; the fixed fields
    /// above only serve as the pre-traffic fallback.
    pub adaptive: Option<AdaptiveServing>,
}

impl ServeConfig {
    /// Static TP-n baseline.
    pub fn tp(n: usize) -> ServeConfig {
        ServeConfig {
            attn: AttnStrategy::new(n, 1),
            expert_prefill: ExpertStrategy::new(n, 1),
            expert_decode: ExpertStrategy::new(n, 1),
            policy: RouterPolicy::Fcfs,
            queue_capacity: 1024,
            adaptive: None,
        }
    }

    /// HAP-style phase-specific plan: EP prefill → TP decode.
    pub fn hap_transition(n: usize) -> ServeConfig {
        ServeConfig {
            attn: AttnStrategy::new(n, 1),
            expert_prefill: ExpertStrategy::new(1, n),
            expert_decode: ExpertStrategy::new(n, 1),
            policy: RouterPolicy::Fcfs,
            queue_capacity: 1024,
            adaptive: None,
        }
    }

    /// Online-adaptive serving: per-batch strategy selection driven by
    /// the traffic window, plan cache, and switch controller, planned
    /// for the real tiny-MoE deployment on `n` simulated CPU devices.
    /// Override `adaptive.model` / `adaptive.node` to adapt for a
    /// different deployment.
    pub fn adaptive(n: usize) -> ServeConfig {
        let mut config = Self::tp(n);
        config.adaptive = Some(AdaptiveServing {
            model: MoEModelConfig::tiny_moe(),
            node: NodeConfig::cpu_sim(n),
            controller: ControllerConfig::default(),
            window_capacity: 64,
            plan_cache: None,
        });
        config
    }

    pub fn has_transition(&self) -> bool {
        self.expert_prefill != self.expert_decode
    }

    pub fn label(&self) -> String {
        if self.adaptive.is_some() {
            format!("adaptive (fallback attn={})", self.attn.label())
        } else if self.has_transition() {
            format!(
                "attn={} experts={}→{}",
                self.attn.label(),
                self.expert_prefill.label(),
                self.expert_decode.label()
            )
        } else {
            format!("attn={} experts={}", self.attn.label(), self.expert_prefill.label())
        }
    }
}

/// Per-run state of the adaptation loop: the shared [`AdaptLoop`]
/// (the exact implementation the replay acceptance tests validate)
/// plus the platform's latency model, resolved once so the per-batch
/// path never touches the global model-cache lock.
struct AdaptState {
    control: AdaptLoop,
    latency: std::sync::Arc<crate::sim::LatencyModel>,
}

impl AdaptState {
    fn new(cfg: &AdaptiveServing) -> AdaptState {
        let mut control = AdaptLoop::new(cfg.controller.clone(), cfg.window_capacity);
        if let Some(path) = &cfg.plan_cache {
            match PlanCache::load(path, &cfg.model, &cfg.node) {
                Ok(cache) => control.cache = cache,
                Err(e) => eprintln!("plan cache {}: {e:#} (starting cold)", path.display()),
            }
        }
        AdaptState {
            control,
            latency: crate::sim::LatencyModel::cached(&cfg.node.gpu, PLANNER_SEED),
        }
    }

    /// Observe one packed batch (plus the previous batch's measured
    /// latency, closing the loop on mispredicted plans) and return the
    /// (prefill, decode) plans the controller lands on. The grid engine
    /// executes whatever the planner picked — hybrids included.
    fn select(
        &mut self,
        cfg: &AdaptiveServing,
        requests: &[Request],
        measured: Option<f64>,
    ) -> Result<(ShardPlan, ShardPlan)> {
        let planner = HapPlanner::with_latency(&cfg.model, &cfg.node, self.latency.clone());
        let samples = requests.iter().map(|req| TrafficSample {
            prompt: req.prompt.len(),
            generate: req.max_new_tokens,
            batch: requests.len(),
        });
        let (plan, _) = self.control.step(&planner, samples, None, measured)?;
        Ok((
            ShardPlan::new(plan.attn, plan.expert_prefill),
            ShardPlan::new(plan.attn, plan.expert_decode),
        ))
    }
}

/// Aggregate results of a serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub metrics: Metrics,
    pub responses: Vec<Response>,
    /// Measured compute split (seconds).
    pub prefill_time: f64,
    pub decode_time: f64,
}

/// Serve a whole workload to completion on the PJRT artifacts: builds
/// one executor for the run and delegates to [`serve_on`].
pub fn serve_workload(
    rt: &PjrtRuntime,
    config: &ServeConfig,
    workload: Vec<Request>,
) -> Result<ServeReport> {
    let mut exec = ModelExecutor::new(rt)?;
    serve_on(&mut exec, config, workload)
}

/// Serve a whole workload on one long-lived executor (the synchronous
/// core the worker thread loops over). The executor's shard state
/// persists across batches: weight uploads happen once per layout, and
/// only adaptive plan switches re-materialize shards.
pub fn serve_on(
    exec: &mut ModelExecutor,
    config: &ServeConfig,
    workload: Vec<Request>,
) -> Result<ServeReport> {
    let m = exec.meta().clone();
    let batcher = Batcher::new(m.batch, m.prefill_len, m.max_len - m.prefill_len);
    let mut router = Router::new(config.queue_capacity, config.policy);
    for req in workload {
        if !router.submit(req) {
            anyhow::bail!("router rejected request (queue capacity {})", config.queue_capacity);
        }
    }

    let fixed_prefill = ShardPlan::new(config.attn, config.expert_prefill);
    let fixed_decode = ShardPlan::new(config.attn, config.expert_decode);
    let mut adapt = config.adaptive.as_ref().map(AdaptState::new);
    let stats0 = exec.stats();

    let mut metrics = Metrics::new();
    let mut responses = Vec::new();
    let mut prefill_time = 0.0;
    let mut decode_time = 0.0;
    let mut last_measured: Option<f64> = None;
    let run_start = Instant::now();

    while !router.is_empty() {
        let batch = batcher.pack(router.take(m.batch));
        // Per-batch strategy selection (adaptive) or the fixed plan.
        let (prefill_plan, decode_plan) = match (&mut adapt, &config.adaptive) {
            (Some(state), Some(cfg)) => {
                let switches_before = state.control.controller.switches;
                let picked = state.select(cfg, &batch.requests, last_measured)?;
                metrics.replans += state.control.controller.switches - switches_before;
                picked
            }
            _ => (fixed_prefill, fixed_decode),
        };
        // Declare the batch's plans: evicts stale layouts, materializes
        // missing shards — the measured resharding work of a switch.
        exec.begin_batch(&prefill_plan, &decode_plan)?;

        // ---- Prefill.
        let t0 = Instant::now();
        let logits = exec.prefill(&batch.tokens, &prefill_plan)?;
        let batch_prefill = t0.elapsed().as_secs_f64();
        prefill_time += batch_prefill;
        metrics.batches_prefilled += 1;
        if prefill_plan.expert != decode_plan.expert {
            metrics.transitions += 1;
        }

        let first = argmax_rows(&logits);
        let first_time = Instant::now();
        let mut generated: Vec<Vec<i32>> = (0..batch.live())
            .map(|slot| vec![first[slot] as i32])
            .collect();
        let mut last: Vec<i32> = first.iter().map(|&t| t as i32).collect();
        let mut remaining = batch.remaining.clone();
        for r in remaining.iter_mut().take(batch.live()) {
            *r = r.saturating_sub(1);
        }

        // ---- Decode until every live slot finishes.
        let t0 = Instant::now();
        while remaining.iter().take(batch.live()).any(|&r| r > 0) {
            let logits = exec.decode_step(&last, &decode_plan)?;
            metrics.decode_steps += 1;
            let next = argmax_rows(&logits);
            for slot in 0..batch.live() {
                if remaining[slot] > 0 {
                    generated[slot].push(next[slot] as i32);
                    remaining[slot] -= 1;
                }
            }
            last = next.iter().map(|&t| t as i32).collect();
        }
        let batch_decode = t0.elapsed().as_secs_f64();
        decode_time += batch_decode;
        // Feed the measured latency of this batch into the next
        // adaptation step (demotes consistently mispredicted plans).
        last_measured = Some(batch_prefill + batch_decode);

        // ---- Retire.
        let now = Instant::now();
        for (slot, req) in batch.requests.iter().enumerate() {
            let latency = now.duration_since(req.arrived).as_secs_f64();
            let ttft = first_time.duration_since(req.arrived).as_secs_f64();
            metrics.observe_request(latency, ttft, generated[slot].len());
            responses.push(Response {
                id: req.id,
                tokens: generated[slot].clone(),
                latency,
                ttft,
            });
        }
    }

    metrics.wall_time = run_start.elapsed().as_secs_f64();
    let stats = exec.stats();
    metrics.weight_uploads = stats.materializations - stats0.materializations;
    metrics.reshards = stats.reshards - stats0.reshards;
    metrics.reshard_time = stats.reshard_seconds - stats0.reshard_seconds;

    // Persist the warmed plan cache for the next run.
    if let (Some(state), Some(cfg)) = (&adapt, &config.adaptive) {
        if let Some(path) = &cfg.plan_cache {
            if let Err(e) = state.control.cache.save(path) {
                eprintln!("could not save plan cache {}: {e:#}", path.display());
            }
        }
    }
    Ok(ServeReport { metrics, responses, prefill_time, decode_time })
}

/// Spawn the server on a worker thread; returns a submission handle.
pub struct ServerHandle {
    tx: std::sync::mpsc::Sender<Request>,
    done_rx: std::sync::mpsc::Receiver<ServeReport>,
}

impl ServerHandle {
    pub fn submit(&self, req: Request) -> Result<()> {
        self.tx
            .send(req)
            .map_err(|_| anyhow::anyhow!("server thread terminated"))
    }

    /// Close the submission channel and wait for the final report.
    pub fn finish(self) -> Result<ServeReport> {
        drop(self.tx);
        self.done_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server thread panicked"))
    }
}

/// Run the server on its own thread, collecting requests until the
/// handle is finished, then serving everything and reporting.
///
/// The PJRT runtime is not `Send` (FFI handles), so the thread owns its
/// own runtime loaded from `artifacts_dir`.
pub fn spawn_server(
    artifacts_dir: std::path::PathBuf,
    config: ServeConfig,
) -> Result<ServerHandle> {
    let (tx, rx) = std::sync::mpsc::channel::<Request>();
    let (done_tx, done_rx) = std::sync::mpsc::channel::<ServeReport>();
    std::thread::spawn(move || {
        let rt = match PjrtRuntime::load(&artifacts_dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("server: failed to load artifacts: {e:#}");
                return;
            }
        };
        let workload: Vec<Request> = rx.iter().collect();
        match serve_workload(&rt, &config, workload) {
            Ok(report) => {
                let _ = done_tx.send(report);
            }
            Err(e) => eprintln!("server: serving failed: {e:#}"),
        }
    });
    Ok(ServerHandle { tx, done_rx })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::DeviceGrid;

    #[test]
    fn configs_label_correctly() {
        assert_eq!(ServeConfig::tp(4).label(), "attn=TP4 experts=TP4");
        let h = ServeConfig::hap_transition(4);
        assert!(h.has_transition());
        assert_eq!(h.label(), "attn=TP4 experts=EP4→TP4");
        assert!(ServeConfig::adaptive(4).label().contains("adaptive"));
    }

    #[test]
    fn adaptive_selection_returns_native_grid_plans() {
        // The adaptation loop needs no runtime: feed it a batch of
        // requests and check it lands on plans that lower to
        // well-formed device grids at the node's device count — the
        // planner's pick is executed natively (hybrid EP×TP included),
        // never projected onto a pure layout.
        let config = ServeConfig::adaptive(4);
        let acfg = config.adaptive.as_ref().unwrap();
        let mut state = AdaptState::new(acfg);
        let reqs: Vec<Request> =
            (0..4).map(|i| Request::new(i, vec![1; 24], 16)).collect();
        let (pre, dec) = state.select(acfg, &reqs, None).unwrap();
        assert_eq!(pre.attn, dec.attn, "attention is pinned across stages");
        for plan in [&pre, &dec] {
            assert_eq!(plan.devices(), 4);
            let grid = DeviceGrid::lower(plan).unwrap();
            let m = acfg.model.clone();
            grid.check_dims(m.q_heads, m.kv_heads, m.num_experts, m.moe_inter_size, 4)
                .unwrap();
        }
        assert!(state.control.controller.active().is_some());
        // A second identical batch is a cache hit, not a re-solve.
        state.select(acfg, &reqs, None).unwrap();
        assert_eq!(state.control.cache.hits, 1);
        assert_eq!(state.control.cache.misses, 1);
    }
}
