//! Serving configuration plus the **deprecated run-to-completion entry
//! points**, kept as thin compatibility wrappers over the streaming
//! [`crate::serving::Engine`] core.
//!
//! [`ServeConfig`]/[`AdaptiveServing`] are the typed serving config the
//! engine builder consumes. [`serve_workload`]/[`serve_on`] and
//! [`spawn_server`] predate the engine: they gang-schedule a whole
//! workload to completion and return one [`ServeReport`]. They now
//! delegate to [`crate::serving::engine::serve_with`] with
//! [`crate::serving::Scheduling::Gang`], so admission backpressure
//! (drain instead of `bail!` on a full queue) and the engine's metrics
//! come for free. New code should drive
//! [`crate::serving::Engine`] directly — `submit`/`step`/`poll`/
//! `drain`/`shutdown` — and get continuous batching with in-flight plan
//! switches.

use super::engine::{serve_with, Scheduling};
use super::metrics::Metrics;
use super::router::RouterPolicy;
use super::{Request, Response};
use crate::adapt::controller::ControllerConfig;
use crate::config::{hardware::NodeConfig, model::MoEModelConfig};
use crate::model::{KvLayout, ModelExecutor};
use crate::quant::QuantKind;
use crate::runtime::PjrtRuntime;
use crate::strategy::{AttnStrategy, ExpertStrategy};
use crate::Result;

/// Online-adaptation settings for the serving loop: the planner inputs
/// (deployment model + platform) and the control-loop tunables.
#[derive(Debug, Clone)]
pub struct AdaptiveServing {
    pub model: MoEModelConfig,
    pub node: NodeConfig,
    pub controller: ControllerConfig,
    pub window_capacity: usize,
    /// When set, the plan cache is loaded from this path at startup
    /// (ignored on model/platform fingerprint mismatch) and saved back
    /// at the end of the run.
    pub plan_cache: Option<std::path::PathBuf>,
}

impl AdaptiveServing {
    /// Replace the deployment model with one derived from a loaded
    /// artifact manifest, so the adaptation economics describe the
    /// model actually being served rather than a preset that may have
    /// drifted from the artifacts on disk.
    pub fn with_manifest_model(
        mut self,
        meta: &crate::runtime::manifest::TinyModelMeta,
    ) -> AdaptiveServing {
        let mut model = MoEModelConfig {
            name: "manifest-model".into(),
            params_b: 0.0,
            layers: meta.layers,
            q_heads: meta.q_heads,
            kv_heads: meta.kv_heads,
            hidden: meta.hidden,
            head_dim: meta.head_dim,
            num_experts: meta.num_experts,
            top_k: meta.top_k,
            shared_experts: 0,
            moe_inter_size: meta.inter,
            shared_inter_size: 0,
            vocab: meta.vocab,
            dtype_bytes: 4, // the CPU PJRT artifacts run f32
        };
        model.params_b = model.weight_bytes() as f64 / model.dtype_bytes as f64 / 1e9;
        self.model = model;
        self
    }
}

/// Serving configuration: the hybrid plan to execute, or — when
/// `adaptive` is set — the adaptation loop that re-selects it (per
/// admission boundary in the streaming engine, per batch in gang mode).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub attn: AttnStrategy,
    pub expert_prefill: ExpertStrategy,
    pub expert_decode: ExpertStrategy,
    pub policy: RouterPolicy,
    pub queue_capacity: usize,
    /// Streaming scheduler: maximum prompt tokens prefilled per joiner
    /// per iteration (`0` = unchunked, the whole padded prompt in one
    /// iteration). A non-zero chunk splits a long prompt's prefill
    /// across multiple admission iterations, with peer decode steps
    /// interleaved between chunks — removing the admission
    /// head-of-line block — at bit-identical per-request tokens (the
    /// ranged prefill kernel is exact). The joiner's first token (and
    /// its TTFT) land with the final chunk. Ignored by the gang
    /// scheduler, which has no peers to protect during a prefill.
    pub prefill_chunk: usize,
    /// Micro-chunk pipeline width `K` for the host executor (`1` =
    /// module-sequential, the legacy path). With `K > 1` every expert
    /// layer splits its token batch into `K` ranged chunks so chunk
    /// `i`'s FFN compute overlaps chunk `i-1`'s combine, and the
    /// streaming scheduler batches same-length joiner chunks into one
    /// ranged prefill call. Bit-identical per-request tokens at any
    /// `K` (chunk outputs are exact row ranges concatenated in chunk
    /// order; `EngineMode::Sequential` stays the oracle). Host backend
    /// only. See `hap serve --pipeline-chunks`.
    pub pipeline_chunks: usize,
    /// Streaming scheduler, budget-driven chunk sizing: when `> 0` and
    /// `pipeline_chunks > 1`, joiner prefill chunks are sized from the
    /// **measured** prefill rate (EWMA of tokens/second) so one chunk
    /// costs about this many milliseconds — the per-iteration budget —
    /// instead of the static `prefill_chunk` token count. Sizing is
    /// wall-clock-derived and therefore run-to-run nondeterministic;
    /// tokens stay bit-identical regardless (chunking is exact for any
    /// chunk sizes), but deterministic-trace and fault-schedule
    /// comparisons should keep this at `0`. `0` = static sizing.
    pub prefill_budget_ms: f64,
    /// Weight quantization for the packed host shards (`None` = f32).
    /// Host backend + blocked kernels only; applied to the executor by
    /// the engine builder / `serve_with` before any shard goes
    /// resident. See `hap serve --quant int8|int4`.
    pub quant: Option<QuantKind>,
    /// KV-cache memory layout (`Padded` = per-slot `max_len` rows, the
    /// default; `Paged` = the block-pool layout with copy-on-write
    /// prompt-prefix sharing — see [`crate::model::paged_kv`]).
    /// Streaming scheduler + host backend only; admission switches
    /// from free-slot counting to free-block accounting. See
    /// `hap serve --kv paged`.
    pub kv: KvLayout,
    /// When set, the engine runs window → plan cache → controller and
    /// executes under the controller's active plan; the fixed fields
    /// above only serve as the pre-traffic fallback.
    pub adaptive: Option<AdaptiveServing>,
}

impl ServeConfig {
    /// Static TP-n baseline.
    pub fn tp(n: usize) -> ServeConfig {
        ServeConfig {
            attn: AttnStrategy::new(n, 1),
            expert_prefill: ExpertStrategy::new(n, 1),
            expert_decode: ExpertStrategy::new(n, 1),
            policy: RouterPolicy::Fcfs,
            queue_capacity: 1024,
            prefill_chunk: 0,
            pipeline_chunks: 1,
            prefill_budget_ms: 0.0,
            quant: None,
            kv: KvLayout::Padded,
            adaptive: None,
        }
    }

    /// HAP-style phase-specific plan: EP prefill → TP decode.
    pub fn hap_transition(n: usize) -> ServeConfig {
        ServeConfig {
            attn: AttnStrategy::new(n, 1),
            expert_prefill: ExpertStrategy::new(1, n),
            expert_decode: ExpertStrategy::new(n, 1),
            policy: RouterPolicy::Fcfs,
            queue_capacity: 1024,
            prefill_chunk: 0,
            pipeline_chunks: 1,
            prefill_budget_ms: 0.0,
            quant: None,
            kv: KvLayout::Padded,
            adaptive: None,
        }
    }

    /// Online-adaptive serving: strategy re-selection driven by the
    /// traffic window, plan cache, and switch controller, planned for
    /// the real tiny-MoE deployment on `n` simulated CPU devices.
    /// Override `adaptive.model` / `adaptive.node` to adapt for a
    /// different deployment.
    pub fn adaptive(n: usize) -> ServeConfig {
        let mut config = Self::tp(n);
        config.adaptive = Some(AdaptiveServing {
            model: MoEModelConfig::tiny_moe(),
            node: NodeConfig::cpu_sim(n),
            controller: ControllerConfig::default(),
            window_capacity: 64,
            plan_cache: None,
        });
        config
    }

    pub fn has_transition(&self) -> bool {
        self.expert_prefill != self.expert_decode
    }

    pub fn label(&self) -> String {
        let base = if self.adaptive.is_some() {
            format!("adaptive (fallback attn={})", self.attn.label())
        } else if self.has_transition() {
            format!(
                "attn={} experts={}→{}",
                self.attn.label(),
                self.expert_prefill.label(),
                self.expert_decode.label()
            )
        } else {
            format!("attn={} experts={}", self.attn.label(), self.expert_prefill.label())
        };
        let base = match self.quant {
            Some(q) => format!("{base} quant={}", q.name()),
            None => base,
        };
        match self.kv {
            KvLayout::Paged { block_size, .. } => format!("{base} kv=paged/{block_size}"),
            KvLayout::Padded => base,
        }
    }
}

/// Aggregate results of a serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub metrics: Metrics,
    pub responses: Vec<Response>,
    /// Measured compute split (seconds).
    pub prefill_time: f64,
    pub decode_time: f64,
    /// Snapshot of the run's metrics as a typed registry
    /// (counter/gauge/histogram), exportable as JSON or
    /// Prometheus-style text (`hap serve --metrics-out`).
    pub telemetry: crate::obs::Registry,
    /// The deterministic event trace, when the run was driven with an
    /// enabled recorder ([`crate::serving::serve_with_recorder`],
    /// `EngineBuilder::recorder`); empty otherwise.
    pub trace: Vec<crate::obs::TraceEvent>,
}

/// Typed config rejection from the deprecated gang-mode wrappers
/// ([`serve_workload`]/[`serve_on`]): streaming-scheduler knobs used to
/// be accepted and silently ignored there — a config that *looks* like
/// it chunks or pipelines prefill but doesn't. The wrappers now refuse
/// the combination up front; drive [`crate::serving::Engine`] (or
/// [`serve_with`] with [`Scheduling::Streaming`]) to actually use the
/// knob, or zero it for gang scheduling.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum GangConfigError {
    /// `prefill_chunk != 0`: gang prefill owns the whole padded batch
    /// in one shot; there are no peers to protect between chunks.
    PrefillChunk { tokens: usize },
    /// `pipeline_chunks > 1`: micro-chunk pipelining is configured per
    /// engine run; the deprecated wrappers predate the knob and never
    /// forwarded it.
    PipelineChunks { chunks: usize },
    /// `prefill_budget_ms > 0`: budget-driven chunk sizing is a
    /// streaming-scheduler feature (it sizes *joiner* chunks against
    /// peer decode iterations, which gang mode doesn't have).
    PrefillBudget { ms: f64 },
}

impl std::fmt::Display for GangConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            GangConfigError::PrefillChunk { tokens } => write!(
                f,
                "prefill_chunk={tokens} is a streaming-scheduler knob; the deprecated gang \
                 wrappers would silently ignore it (use the streaming Engine, or set it to 0)"
            ),
            GangConfigError::PipelineChunks { chunks } => write!(
                f,
                "pipeline_chunks={chunks} is not forwarded by the deprecated gang wrappers \
                 (use the streaming Engine or serve_with, or set it to 1)"
            ),
            GangConfigError::PrefillBudget { ms } => write!(
                f,
                "prefill_budget_ms={ms} is a streaming-scheduler knob; gang prefill has no \
                 per-iteration budget (use the streaming Engine, or set it to 0)"
            ),
        }
    }
}

impl std::error::Error for GangConfigError {}

/// Reject streaming-only knobs on the deprecated gang wrappers with a
/// typed, downcastable error instead of ignoring the fields.
fn check_gang_config(config: &ServeConfig) -> Result<()> {
    if config.prefill_chunk != 0 {
        return Err(GangConfigError::PrefillChunk { tokens: config.prefill_chunk }.into());
    }
    if config.pipeline_chunks > 1 {
        return Err(GangConfigError::PipelineChunks { chunks: config.pipeline_chunks }.into());
    }
    if config.prefill_budget_ms > 0.0 {
        return Err(GangConfigError::PrefillBudget { ms: config.prefill_budget_ms }.into());
    }
    Ok(())
}

/// Deprecated entry point: serve a whole workload to completion on the
/// PJRT artifacts (gang-scheduled). Builds one executor for the run and
/// delegates to [`serve_on`]. New code: [`crate::serving::Engine`].
pub fn serve_workload(
    rt: &PjrtRuntime,
    config: &ServeConfig,
    workload: Vec<Request>,
) -> Result<ServeReport> {
    // Fail before the executor is built: a rejected config shouldn't
    // cost an artifact load.
    check_gang_config(config)?;
    let mut exec = ModelExecutor::new(rt)?;
    serve_on(&mut exec, config, workload)
}

/// Deprecated entry point: serve a whole workload on one caller-owned
/// long-lived executor, gang-scheduled. The executor's shard state
/// persists across batches and across calls. Thin wrapper over the
/// engine core ([`serve_with`] with [`Scheduling::Gang`]); a workload
/// larger than `queue_capacity` drains through scheduler iterations
/// instead of aborting. Streaming-only knobs (`prefill_chunk`,
/// `pipeline_chunks`, `prefill_budget_ms`) are rejected with a typed
/// [`GangConfigError`] rather than silently ignored.
pub fn serve_on(
    exec: &mut ModelExecutor,
    config: &ServeConfig,
    workload: Vec<Request>,
) -> Result<ServeReport> {
    check_gang_config(config)?;
    serve_with(exec, config, Scheduling::Gang, workload)
}

/// Spawn the server on a worker thread; returns a submission handle.
pub struct ServerHandle {
    tx: std::sync::mpsc::Sender<Request>,
    done_rx: std::sync::mpsc::Receiver<Result<ServeReport>>,
}

impl ServerHandle {
    pub fn submit(&self, req: Request) -> Result<()> {
        self.tx
            .send(req)
            .map_err(|_| anyhow::anyhow!("server thread terminated"))
    }

    /// Close the submission channel and wait for the final report. A
    /// serving failure on the worker thread surfaces here as the real
    /// error (the done channel carries `Result<ServeReport>`); only an
    /// actual thread death reports as a panic.
    pub fn finish(self) -> Result<ServeReport> {
        drop(self.tx);
        match self.done_rx.recv() {
            Ok(result) => result,
            Err(_) => Err(anyhow::anyhow!("server thread panicked")),
        }
    }
}

/// Run the server on its own thread, collecting requests until the
/// handle is finished, then serving everything and reporting.
///
/// The PJRT runtime is not `Send` (FFI handles), so the thread owns its
/// own runtime loaded from `artifacts_dir`. Errors — including a failed
/// artifact load — propagate through the handle instead of being
/// swallowed to stderr.
pub fn spawn_server(
    artifacts_dir: std::path::PathBuf,
    config: ServeConfig,
) -> Result<ServerHandle> {
    let (tx, rx) = std::sync::mpsc::channel::<Request>();
    let (done_tx, done_rx) = std::sync::mpsc::channel::<Result<ServeReport>>();
    std::thread::spawn(move || {
        let rt = match PjrtRuntime::load(&artifacts_dir) {
            Ok(rt) => rt,
            Err(e) => {
                let _ = done_tx.send(Err(e.context(format!(
                    "server: failed to load artifacts from {}",
                    artifacts_dir.display()
                ))));
                return;
            }
        };
        let workload: Vec<Request> = rx.iter().collect();
        let _ = done_tx.send(serve_workload(&rt, &config, workload));
    });
    Ok(ServerHandle { tx, done_rx })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configs_label_correctly() {
        assert_eq!(ServeConfig::tp(4).label(), "attn=TP4 experts=TP4");
        let h = ServeConfig::hap_transition(4);
        assert!(h.has_transition());
        assert_eq!(h.label(), "attn=TP4 experts=EP4→TP4");
        assert!(ServeConfig::adaptive(4).label().contains("adaptive"));
        let mut q = ServeConfig::tp(4);
        q.quant = Some(QuantKind::Int8);
        assert_eq!(q.label(), "attn=TP4 experts=TP4 quant=int8");
    }

    #[test]
    fn gang_wrappers_reject_streaming_knobs_with_typed_errors() {
        // Regression: serve_on/serve_workload used to accept
        // prefill_chunk (and now the pipeline knobs) and silently
        // ignore them — the run "worked" but did something other than
        // what the config asked for. They must fail up front with a
        // downcastable GangConfigError.
        let m = crate::runtime::TinyModelMeta::host_demo();
        let mut exec = ModelExecutor::host(crate::model::WeightStore::synthetic(&m, 1));
        let cases: Vec<(ServeConfig, GangConfigError)> = vec![
            (
                ServeConfig { prefill_chunk: 8, ..ServeConfig::tp(4) },
                GangConfigError::PrefillChunk { tokens: 8 },
            ),
            (
                ServeConfig { pipeline_chunks: 4, ..ServeConfig::tp(4) },
                GangConfigError::PipelineChunks { chunks: 4 },
            ),
            (
                ServeConfig { prefill_budget_ms: 2.5, ..ServeConfig::tp(4) },
                GangConfigError::PrefillBudget { ms: 2.5 },
            ),
        ];
        for (config, want) in cases {
            let err = serve_on(&mut exec, &config, Vec::new())
                .expect_err("gang wrapper must reject streaming-only knobs");
            let got = err
                .downcast_ref::<GangConfigError>()
                .unwrap_or_else(|| panic!("untyped error: {err:#}"));
            assert_eq!(*got, want);
        }
        // The defaults still serve (empty workload: an immediate,
        // clean no-op run).
        let report = serve_on(&mut exec, &ServeConfig::tp(4), Vec::new()).unwrap();
        assert!(report.responses.is_empty());
    }

    #[test]
    fn spawn_server_propagates_load_errors_through_finish() {
        // Regression for the swallowed-error path: a bad artifacts dir
        // used to print to stderr and report "server thread panicked";
        // the Result-carrying done channel must surface the real cause.
        let handle = spawn_server(
            std::path::PathBuf::from("/nonexistent/hap-artifacts"),
            ServeConfig::tp(1),
        )
        .unwrap();
        let err = handle.finish().expect_err("missing artifacts must fail");
        let rendered = format!("{err:#}");
        assert!(
            rendered.contains("failed to load artifacts"),
            "real error lost: {rendered}"
        );
        assert!(
            !rendered.contains("panicked"),
            "load failure misreported as a panic: {rendered}"
        );
    }
}
