//! The serving loop: drains the router, packs batches, executes
//! prefill + decode on the real PJRT model under a hybrid plan, and
//! reports per-request + aggregate metrics.
//!
//! `serve_workload` is the synchronous core used by the examples,
//! benches, and the `hap serve` CLI; `spawn_server` wraps it in a
//! worker thread with mpsc channels for concurrent submitters.

use super::batcher::Batcher;
use super::metrics::Metrics;
use super::router::{Router, RouterPolicy};
use super::{Request, Response};
use crate::model::{ModelExecutor, StageStrategy};
use crate::runtime::literal::argmax_rows;
use crate::runtime::PjrtRuntime;
use crate::strategy::ExpertStrategy;
use crate::Result;
use std::time::Instant;

/// Serving configuration: the hybrid plan to execute.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub attn_tp: usize,
    pub expert_prefill: ExpertStrategy,
    pub expert_decode: ExpertStrategy,
    pub policy: RouterPolicy,
    pub queue_capacity: usize,
}

impl ServeConfig {
    /// Static TP-n baseline.
    pub fn tp(n: usize) -> ServeConfig {
        ServeConfig {
            attn_tp: n,
            expert_prefill: ExpertStrategy::new(n, 1),
            expert_decode: ExpertStrategy::new(n, 1),
            policy: RouterPolicy::Fcfs,
            queue_capacity: 1024,
        }
    }

    /// HAP-style phase-specific plan: EP prefill → TP decode.
    pub fn hap_transition(n: usize) -> ServeConfig {
        ServeConfig {
            attn_tp: n,
            expert_prefill: ExpertStrategy::new(1, n),
            expert_decode: ExpertStrategy::new(n, 1),
            policy: RouterPolicy::Fcfs,
            queue_capacity: 1024,
        }
    }

    pub fn has_transition(&self) -> bool {
        self.expert_prefill != self.expert_decode
    }

    pub fn label(&self) -> String {
        if self.has_transition() {
            format!(
                "attn=TP{} experts={}→{}",
                self.attn_tp,
                self.expert_prefill.label(),
                self.expert_decode.label()
            )
        } else {
            format!("attn=TP{} experts={}", self.attn_tp, self.expert_prefill.label())
        }
    }
}

/// Aggregate results of a serving run.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub metrics: Metrics,
    pub responses: Vec<Response>,
    /// Measured compute split (seconds).
    pub prefill_time: f64,
    pub decode_time: f64,
}

/// Serve a whole workload to completion (synchronous; the unit the
/// worker thread loops over).
pub fn serve_workload(
    rt: &PjrtRuntime,
    config: &ServeConfig,
    workload: Vec<Request>,
) -> Result<ServeReport> {
    let m = &rt.manifest.model;
    let batcher = Batcher::new(m.batch, m.prefill_len, m.max_len - m.prefill_len);
    let mut router = Router::new(config.queue_capacity, config.policy);
    for req in workload {
        if !router.submit(req) {
            anyhow::bail!("router rejected request (queue capacity {})", config.queue_capacity);
        }
    }

    let prefill_strategy =
        StageStrategy { attn_tp: config.attn_tp, expert: config.expert_prefill };
    let decode_strategy = StageStrategy { attn_tp: config.attn_tp, expert: config.expert_decode };

    let mut metrics = Metrics::new();
    let mut responses = Vec::new();
    let mut prefill_time = 0.0;
    let mut decode_time = 0.0;
    let run_start = Instant::now();

    while !router.is_empty() {
        let batch = batcher.pack(router.take(m.batch));
        let mut exec = ModelExecutor::new(rt)?;

        // ---- Prefill.
        let t0 = Instant::now();
        let logits = exec.prefill(&batch.tokens, &prefill_strategy)?;
        prefill_time += t0.elapsed().as_secs_f64();
        metrics.batches_prefilled += 1;
        if config.has_transition() {
            metrics.transitions += 1;
        }

        let first = argmax_rows(&logits);
        let first_time = Instant::now();
        let mut generated: Vec<Vec<i32>> = (0..batch.live())
            .map(|slot| vec![first[slot] as i32])
            .collect();
        let mut last: Vec<i32> = first.iter().map(|&t| t as i32).collect();
        let mut remaining = batch.remaining.clone();
        for r in remaining.iter_mut().take(batch.live()) {
            *r = r.saturating_sub(1);
        }

        // ---- Decode until every live slot finishes.
        let t0 = Instant::now();
        while remaining.iter().take(batch.live()).any(|&r| r > 0) {
            let logits = exec.decode_step(&last, &decode_strategy)?;
            metrics.decode_steps += 1;
            let next = argmax_rows(&logits);
            for slot in 0..batch.live() {
                if remaining[slot] > 0 {
                    generated[slot].push(next[slot] as i32);
                    remaining[slot] -= 1;
                }
            }
            last = next.iter().map(|&t| t as i32).collect();
        }
        decode_time += t0.elapsed().as_secs_f64();

        // ---- Retire.
        let now = Instant::now();
        for (slot, req) in batch.requests.iter().enumerate() {
            let latency = now.duration_since(req.arrived).as_secs_f64();
            let ttft = first_time.duration_since(req.arrived).as_secs_f64();
            metrics.observe_request(latency, ttft, generated[slot].len());
            responses.push(Response {
                id: req.id,
                tokens: generated[slot].clone(),
                latency,
                ttft,
            });
        }
    }

    metrics.wall_time = run_start.elapsed().as_secs_f64();
    Ok(ServeReport { metrics, responses, prefill_time, decode_time })
}

/// Spawn the server on a worker thread; returns a submission handle.
pub struct ServerHandle {
    tx: std::sync::mpsc::Sender<Request>,
    done_rx: std::sync::mpsc::Receiver<ServeReport>,
}

impl ServerHandle {
    pub fn submit(&self, req: Request) -> Result<()> {
        self.tx
            .send(req)
            .map_err(|_| anyhow::anyhow!("server thread terminated"))
    }

    /// Close the submission channel and wait for the final report.
    pub fn finish(self) -> Result<ServeReport> {
        drop(self.tx);
        self.done_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("server thread panicked"))
    }
}

/// Run the server on its own thread, collecting requests until the
/// handle is finished, then serving everything and reporting.
///
/// The PJRT runtime is not `Send` (FFI handles), so the thread owns its
/// own runtime loaded from `artifacts_dir`.
pub fn spawn_server(
    artifacts_dir: std::path::PathBuf,
    config: ServeConfig,
) -> Result<ServerHandle> {
    let (tx, rx) = std::sync::mpsc::channel::<Request>();
    let (done_tx, done_rx) = std::sync::mpsc::channel::<ServeReport>();
    std::thread::spawn(move || {
        let rt = match PjrtRuntime::load(&artifacts_dir) {
            Ok(rt) => rt,
            Err(e) => {
                eprintln!("server: failed to load artifacts: {e:#}");
                return;
            }
        };
        let workload: Vec<Request> = rx.iter().collect();
        match serve_workload(&rt, &config, workload) {
            Ok(report) => {
                let _ = done_tx.send(report);
            }
            Err(e) => eprintln!("server: serving failed: {e:#}"),
        }
    });
    Ok(ServerHandle { tx, done_rx })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn configs_label_correctly() {
        assert_eq!(ServeConfig::tp(4).label(), "attn=TP4 experts=TP4");
        let h = ServeConfig::hap_transition(4);
        assert!(h.has_transition());
        assert_eq!(h.label(), "attn=TP4 experts=EP4→TP4");
    }
}
