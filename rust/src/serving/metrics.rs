//! Serving metrics: counters + latency histograms with percentile
//! queries (p50/p95/p99), and a throughput window.

use crate::util::stats;

/// Accumulating metrics for a serving run.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub requests_completed: usize,
    pub tokens_generated: usize,
    pub batches_prefilled: usize,
    pub decode_steps: usize,
    pub transitions: usize,
    /// Weight-moving plan switches made by the adaptive controller.
    pub replans: usize,
    /// Shard materializations ("weight uploads") the executor performed
    /// over the run. Flat after the first batch under a fixed plan;
    /// grows only when a plan switch moves weights.
    pub weight_uploads: usize,
    /// Inter-batch plan switches that actually re-materialized shards.
    pub reshards: usize,
    /// Measured seconds the executor spent resharding weights.
    pub reshard_time: f64,
    latencies: Vec<f64>,
    ttfts: Vec<f64>,
    /// Wall-clock duration of the run (set by the server at the end).
    pub wall_time: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn observe_request(&mut self, latency: f64, ttft: f64, tokens: usize) {
        self.requests_completed += 1;
        self.tokens_generated += tokens;
        self.latencies.push(latency);
        self.ttfts.push(ttft);
    }

    pub fn latency_p(&self, q: f64) -> f64 {
        stats::percentile(&self.latencies, q)
    }

    pub fn ttft_p(&self, q: f64) -> f64 {
        stats::percentile(&self.ttfts, q)
    }

    pub fn mean_latency(&self) -> f64 {
        stats::mean(&self.latencies)
    }

    /// Generated tokens per second over the run.
    pub fn throughput(&self) -> f64 {
        if self.wall_time <= 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / self.wall_time
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "{} requests, {} tokens | latency p50 {:.1} ms p95 {:.1} ms p99 {:.1} ms | ttft p50 {:.1} ms | {:.1} tok/s | {} prefills, {} decode steps, {} transitions, {} replans | {} shard uploads, {} reshards ({:.1} ms)",
            self.requests_completed,
            self.tokens_generated,
            self.latency_p(50.0) * 1e3,
            self.latency_p(95.0) * 1e3,
            self.latency_p(99.0) * 1e3,
            self.ttft_p(50.0) * 1e3,
            self.throughput(),
            self.batches_prefilled,
            self.decode_steps,
            self.transitions,
            self.replans,
            self.weight_uploads,
            self.reshards,
            self.reshard_time * 1e3,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_throughput() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.observe_request(i as f64 / 1000.0, i as f64 / 2000.0, 10);
        }
        m.wall_time = 2.0;
        assert_eq!(m.requests_completed, 100);
        assert_eq!(m.tokens_generated, 1000);
        assert!((m.latency_p(50.0) - 0.0505).abs() < 1e-3);
        assert!(m.latency_p(99.0) > 0.098);
        assert_eq!(m.throughput(), 500.0);
        assert!(m.summary().contains("100 requests"));
    }
}
