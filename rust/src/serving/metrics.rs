//! Serving metrics: counters + latency/TTFT/TPOT histograms with
//! percentile queries (p50/p95/p99), slot-occupancy statistics for the
//! streaming scheduler, and a throughput window. [`Metrics::registry`]
//! snapshots everything onto the observability
//! [`Registry`](crate::obs::Registry) for JSON / Prometheus export
//! (`hap serve --metrics-out`, `ServeReport::telemetry`).

use crate::obs::Registry;
use crate::util::stats;

/// Accumulating metrics for a serving run.
#[derive(Debug, Default, Clone)]
pub struct Metrics {
    pub requests_completed: usize,
    pub tokens_generated: usize,
    /// Prefill operations: gang batches, or streaming joiners (one
    /// per admitted request, however many chunks its prefill took).
    pub batches_prefilled: usize,
    /// Streaming prefill chunk executions. Equals `batches_prefilled`
    /// when unchunked (`prefill_chunk = 0`); with an `N`-token chunk a
    /// joiner with an `S`-token padded prompt contributes `⌈S/N⌉`.
    pub prefill_chunks: usize,
    pub decode_steps: usize,
    /// Prefill→decode expert-layout transitions executed (per batch in
    /// gang mode, per admitted request in streaming mode).
    pub transitions: usize,
    /// Weight-moving plan switches made by the adaptive controller.
    pub replans: usize,
    /// Shard materializations ("weight uploads") the executor performed
    /// over the run. Flat after the first batch under a fixed plan;
    /// grows only when a plan switch moves weights.
    pub weight_uploads: usize,
    /// Plan switches that actually re-materialized shards.
    pub reshards: usize,
    /// Measured seconds the executor spent resharding weights.
    pub reshard_time: f64,
    /// Device faults the recovery state machine classified (each
    /// distinct fault episode counts once; see `serving::engine`).
    pub faults_detected: usize,
    /// Bounded deterministic retries scheduled for retryable faults
    /// (`Stall`, `Transient`).
    pub fault_retries: usize,
    /// Degraded re-plans: confirmed device losses that shrank the grid
    /// onto the surviving device subset.
    pub replans_degraded: usize,
    /// In-flight requests requeued and replayed from their prompt by a
    /// degraded re-plan (bit-identical recovery).
    pub requests_recovered: usize,
    /// Requests drained as `RequestStatus::Failed` because no grid
    /// could serve them.
    pub requests_failed: usize,
    /// Paged KV: admissions whose prompt matched a trie-cached prefix
    /// (shared blocks attached, shared prefill work skipped).
    pub prefix_hits: u64,
    /// Paged KV: prompt tokens served from shared prefix blocks
    /// instead of being re-prefilled.
    pub prefix_shared_tokens: u64,
    /// Paged KV: pool blocks owned by at least one slot or trie node
    /// at the last scheduler iteration (gauge; 0 under padded).
    pub kv_blocks_in_use: u64,
    /// Paged KV: free-list blocks at the last scheduler iteration
    /// (gauge; 0 under padded).
    pub kv_blocks_free: u64,
    /// Live (still-generating) slots summed over decode iterations —
    /// `slot_steps / slot_capacity_steps` is the mean occupancy. Gang
    /// convoys leave this low (finished members ride dead); continuous
    /// batching refills slots mid-decode.
    pub slot_steps: usize,
    /// Total slots available summed over decode iterations.
    pub slot_capacity_steps: usize,
    latencies: Vec<f64>,
    ttfts: Vec<f64>,
    /// Per-request time-per-output-token (decode seconds / generated
    /// tokens after the first), the streaming-latency companion to TTFT.
    tpots: Vec<f64>,
    /// Wall-clock duration of the run (set by the server at the end).
    pub wall_time: f64,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn observe_request(&mut self, latency: f64, ttft: f64, tokens: usize) {
        self.requests_completed += 1;
        self.tokens_generated += tokens;
        self.latencies.push(latency);
        self.ttfts.push(ttft);
        // TPOT is only defined past the first token: a request that
        // never decoded would contribute a degenerate sample (gang
        // convoy wait, or ~0 under streaming's retire-at-admission).
        if tokens > 1 {
            self.tpots.push((latency - ttft).max(0.0) / (tokens - 1) as f64);
        }
    }

    /// Record one decode iteration's slot usage: `live` slots doing
    /// useful work out of `capacity` batch slots.
    pub fn observe_occupancy(&mut self, live: usize, capacity: usize) {
        self.slot_steps += live;
        self.slot_capacity_steps += capacity;
    }

    /// Mean fraction of batch slots doing useful work per decode
    /// iteration (1.0 = perfectly packed).
    pub fn mean_occupancy(&self) -> f64 {
        if self.slot_capacity_steps == 0 {
            0.0
        } else {
            self.slot_steps as f64 / self.slot_capacity_steps as f64
        }
    }

    pub fn latency_p(&self, q: f64) -> f64 {
        stats::percentile(&self.latencies, q)
    }

    pub fn ttft_p(&self, q: f64) -> f64 {
        stats::percentile(&self.ttfts, q)
    }

    pub fn tpot_p(&self, q: f64) -> f64 {
        stats::percentile(&self.tpots, q)
    }

    pub fn mean_latency(&self) -> f64 {
        stats::mean(&self.latencies)
    }

    pub fn mean_ttft(&self) -> f64 {
        stats::mean(&self.ttfts)
    }

    pub fn mean_tpot(&self) -> f64 {
        stats::mean(&self.tpots)
    }

    /// Set the run's wall-clock duration exactly once: the first call
    /// wins, later calls are no-ops. The streaming engine finalizes in
    /// `Session::finish`, but callers that already hold a report (the
    /// server shutdown path) historically re-stamped `wall_time` — the
    /// set-once contract makes double-finalization harmless and
    /// guarantees a completed run can never report 0.0 tok/s.
    pub fn finalize_wall(&mut self, seconds: f64) {
        if self.wall_time <= 0.0 {
            self.wall_time = seconds.max(1e-9);
        }
    }

    /// Generated tokens per second over the run.
    pub fn throughput(&self) -> f64 {
        if self.wall_time <= 0.0 {
            0.0
        } else {
            self.tokens_generated as f64 / self.wall_time
        }
    }

    /// Snapshot every counter, gauge, and distribution onto the
    /// observability registry (insertion-ordered, so both expositions
    /// are deterministic).
    pub fn registry(&self) -> Registry {
        let mut r = Registry::new();
        r.counter("requests_completed", self.requests_completed as u64);
        r.counter("tokens_generated", self.tokens_generated as u64);
        r.counter("batches_prefilled", self.batches_prefilled as u64);
        r.counter("prefill_chunks", self.prefill_chunks as u64);
        r.counter("decode_steps", self.decode_steps as u64);
        r.counter("transitions", self.transitions as u64);
        r.counter("replans", self.replans as u64);
        r.counter("weight_uploads", self.weight_uploads as u64);
        r.counter("reshards", self.reshards as u64);
        r.gauge("reshard_time_seconds", self.reshard_time);
        r.counter("faults_detected", self.faults_detected as u64);
        r.counter("fault_retries", self.fault_retries as u64);
        r.counter("replans_degraded", self.replans_degraded as u64);
        r.counter("requests_recovered", self.requests_recovered as u64);
        r.counter("requests_failed", self.requests_failed as u64);
        r.counter("prefix_hits", self.prefix_hits);
        r.counter("prefix_shared_tokens", self.prefix_shared_tokens);
        r.gauge("kv_blocks_in_use", self.kv_blocks_in_use as f64);
        r.gauge("kv_blocks_free", self.kv_blocks_free as f64);
        r.gauge("slot_occupancy", self.mean_occupancy());
        r.gauge("wall_time_seconds", self.wall_time);
        r.gauge("throughput_tokens_per_second", self.throughput());
        r.histogram("request_latency_seconds", &self.latencies);
        r.histogram("ttft_seconds", &self.ttfts);
        r.histogram("tpot_seconds", &self.tpots);
        r
    }

    pub fn summary(&self) -> String {
        // Empty distributions render as `-`, not a misleading `0.0 ms`.
        let ms = |samples: &[f64], q: f64| {
            if samples.is_empty() {
                "-".to_string()
            } else {
                format!("{:.1} ms", stats::percentile(samples, q) * 1e3)
            }
        };
        let tpot_ms = if self.tpots.is_empty() {
            "-".to_string()
        } else {
            format!("{:.2} ms", self.tpot_p(50.0) * 1e3)
        };
        let mut s = format!(
            "{} requests, {} tokens | latency p50 {} p95 {} p99 {} | ttft p50 {} | tpot p50 {} | {:.1} tok/s | occupancy {:.0}% | {} prefills ({} chunks), {} decode steps, {} transitions, {} replans | {} shard uploads, {} reshards ({:.1} ms)",
            self.requests_completed,
            self.tokens_generated,
            ms(&self.latencies, 50.0),
            ms(&self.latencies, 95.0),
            ms(&self.latencies, 99.0),
            ms(&self.ttfts, 50.0),
            tpot_ms,
            self.throughput(),
            self.mean_occupancy() * 100.0,
            self.batches_prefilled,
            self.prefill_chunks,
            self.decode_steps,
            self.transitions,
            self.replans,
            self.weight_uploads,
            self.reshards,
            self.reshard_time * 1e3,
        );
        if self.kv_blocks_in_use > 0 || self.kv_blocks_free > 0 || self.prefix_hits > 0 {
            s.push_str(&format!(
                " | kv blocks: {} in use, {} free, {} prefix hits ({} shared tokens)",
                self.kv_blocks_in_use,
                self.kv_blocks_free,
                self.prefix_hits,
                self.prefix_shared_tokens,
            ));
        }
        if self.faults_detected > 0 || self.requests_failed > 0 {
            s.push_str(&format!(
                " | faults: {} detected, {} retries, {} degraded replans, {} recovered, {} failed",
                self.faults_detected,
                self.fault_retries,
                self.replans_degraded,
                self.requests_recovered,
                self.requests_failed,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_and_throughput() {
        let mut m = Metrics::new();
        for i in 1..=100 {
            m.observe_request(i as f64 / 1000.0, i as f64 / 2000.0, 10);
        }
        m.wall_time = 2.0;
        assert_eq!(m.requests_completed, 100);
        assert_eq!(m.tokens_generated, 1000);
        assert!((m.latency_p(50.0) - 0.0505).abs() < 1e-3);
        assert!(m.latency_p(99.0) > 0.098);
        assert_eq!(m.throughput(), 500.0);
        assert!(m.summary().contains("100 requests"));
    }

    #[test]
    fn fault_counters_surface_in_summary() {
        let mut m = Metrics::new();
        assert!(!m.summary().contains("faults:"), "fault tail only under faults");
        m.faults_detected = 1;
        m.fault_retries = 2;
        m.replans_degraded = 1;
        m.requests_recovered = 3;
        assert!(m.summary().contains(
            "faults: 1 detected, 2 retries, 1 degraded replans, 3 recovered, 0 failed"
        ));
    }

    #[test]
    fn finalize_wall_is_set_once() {
        // Regression: streaming shutdown used to re-stamp wall_time on
        // a report whose session had already finalized it, so a fast
        // second stamp (or a zero one) could zero out throughput.
        let mut m = Metrics::new();
        m.observe_request(0.5, 0.1, 10);
        assert_eq!(m.throughput(), 0.0, "no wall time yet");
        m.finalize_wall(2.0);
        assert_eq!(m.throughput(), 5.0);
        m.finalize_wall(1000.0); // later stamp must not win
        assert_eq!(m.wall_time, 2.0);
        assert_eq!(m.throughput(), 5.0);
        // Degenerate zero-duration runs clamp instead of dividing by 0.
        let mut z = Metrics::new();
        z.observe_request(0.0, 0.0, 3);
        z.finalize_wall(0.0);
        assert!(z.wall_time > 0.0);
        assert!(z.throughput() > 0.0, "completed run must not report 0 tok/s");
    }

    #[test]
    fn empty_distributions_render_as_dash() {
        let m = Metrics::new();
        let s = m.summary();
        assert!(s.contains("latency p50 - p95 - p99 -"), "got: {s}");
        assert!(s.contains("ttft p50 -"));
        assert!(s.contains("tpot p50 -"));
        // With samples, real values come back.
        let mut m = Metrics::new();
        m.observe_request(0.5, 0.1, 10);
        assert!(m.summary().contains("latency p50 500.0 ms"));
        // A request that never decoded keeps TPOT empty while latency
        // is populated — the dash is per-distribution.
        let mut one = Metrics::new();
        one.observe_request(0.5, 0.5, 1);
        let s = one.summary();
        assert!(s.contains("latency p50 500.0 ms"));
        assert!(s.contains("tpot p50 -"), "got: {s}");
    }

    #[test]
    fn registry_snapshot_exports_counters_and_histograms() {
        use crate::obs::MetricValue;
        let mut m = Metrics::new();
        m.observe_request(0.4, 0.1, 10);
        m.observe_request(0.6, 0.2, 10);
        m.decode_steps = 18;
        m.observe_occupancy(3, 4);
        m.finalize_wall(2.0);
        let r = m.registry();
        assert_eq!(r.get("requests_completed"), Some(&MetricValue::Counter(2)));
        assert_eq!(r.get("decode_steps"), Some(&MetricValue::Counter(18)));
        match r.get("request_latency_seconds") {
            Some(MetricValue::Histogram(h)) => {
                assert_eq!(h.count, 2);
                assert!((h.mean - 0.5).abs() < 1e-12);
            }
            other => panic!("latency should be a histogram, got {other:?}"),
        }
        match r.get("throughput_tokens_per_second") {
            Some(MetricValue::Gauge(g)) => assert_eq!(*g, 10.0),
            other => panic!("throughput should be a gauge, got {other:?}"),
        }
        // Both expositions render without panicking and agree on names.
        assert!(r.to_prometheus().contains("hap_ttft_seconds"));
        assert!(r.to_json().get("tpot_seconds").is_some());
    }

    #[test]
    fn paged_kv_counters_surface_in_registry_and_summary() {
        use crate::obs::MetricValue;
        let mut m = Metrics::new();
        assert!(!m.summary().contains("kv blocks:"), "paged tail only under paged KV");
        m.prefix_hits = 3;
        m.prefix_shared_tokens = 24;
        m.kv_blocks_in_use = 10;
        m.kv_blocks_free = 14;
        let r = m.registry();
        assert_eq!(r.get("prefix_hits"), Some(&MetricValue::Counter(3)));
        assert_eq!(r.get("prefix_shared_tokens"), Some(&MetricValue::Counter(24)));
        assert_eq!(r.get("kv_blocks_in_use"), Some(&MetricValue::Gauge(10.0)));
        assert_eq!(r.get("kv_blocks_free"), Some(&MetricValue::Gauge(14.0)));
        assert!(m
            .summary()
            .contains("kv blocks: 10 in use, 14 free, 3 prefix hits (24 shared tokens)"));
    }

    #[test]
    fn tpot_and_occupancy() {
        let mut m = Metrics::new();
        // 10 tokens, 1 from prefill: latency-ttft spread over 9 steps.
        m.observe_request(1.0, 0.1, 10);
        assert!((m.mean_tpot() - 0.1).abs() < 1e-9);
        assert!((m.tpot_p(50.0) - 0.1).abs() < 1e-9);
        // A single-token request contributes no TPOT sample (it never
        // decoded), so the distribution is unchanged.
        m.observe_request(0.5, 0.5, 1);
        assert!((m.mean_tpot() - 0.1).abs() < 1e-9);
        assert_eq!(m.mean_occupancy(), 0.0, "no decode iterations yet");
        m.observe_occupancy(4, 4);
        m.observe_occupancy(1, 4);
        assert!((m.mean_occupancy() - 5.0 / 8.0).abs() < 1e-9);
        assert!(m.summary().contains("occupancy"));
    }
}
