//! Request router: admission, queueing, and batch-slot assignment.
//!
//! Modeled on the vLLM router's role: requests land in a bounded queue
//! (backpressure by rejection when full — the engine turns rejection
//! into drain-based backpressure), and the scheduler takes them in
//! arrival order or shortest-job-first.
//!
//! **SJF aging.** Pure SJF starves long requests under a steady stream
//! of short ones — fatal for the streaming engine, whose admission runs
//! every iteration. The router therefore tracks, per queued request,
//! how many `take` rounds it has waited; once a request has waited
//! `aging_rounds` rounds it is force-promoted to the front of the queue
//! (stably — starved requests keep their relative order), bounding the
//! wait of any request at `aging_rounds` rounds plus the starved set
//! ahead of it at promotion time.

use super::Request;
use std::collections::VecDeque;

/// Default `take` rounds before a starved request is force-promoted.
pub const DEFAULT_AGING_ROUNDS: usize = 16;

/// Queue discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// First come, first served.
    Fcfs,
    /// Shortest (requested generation) job first — reduces p50 at some
    /// tail cost; aging bounds the tail (see module docs).
    Sjf,
}

/// Bounded admission queue.
#[derive(Debug)]
pub struct Router {
    /// Queued requests with the `round` they were enqueued at.
    queue: VecDeque<(Request, u64)>,
    pub capacity: usize,
    pub policy: RouterPolicy,
    pub rejected: usize,
    pub admitted: usize,
    /// SJF starvation bound in `take` rounds (0 disables promotion).
    pub aging_rounds: usize,
    /// Promotion *events* (not distinct requests: a starved request
    /// that younger short jobs keep SJF-inserting ahead of is
    /// re-promoted each round until it drains).
    pub promoted: usize,
    round: u64,
}

impl Router {
    pub fn new(capacity: usize, policy: RouterPolicy) -> Router {
        Router {
            queue: VecDeque::new(),
            capacity,
            policy,
            rejected: 0,
            admitted: 0,
            aging_rounds: DEFAULT_AGING_ROUNDS,
            promoted: 0,
            round: 0,
        }
    }

    /// Override the SJF aging bound (0 disables promotion).
    pub fn with_aging(mut self, rounds: usize) -> Router {
        self.aging_rounds = rounds;
        self
    }

    /// Admit a request; on backpressure (queue full) the request is
    /// handed back to the caller instead of being dropped.
    pub fn try_submit(&mut self, req: Request) -> Option<Request> {
        if self.queue.len() >= self.capacity {
            self.rejected += 1;
            return Some(req);
        }
        self.admitted += 1;
        match self.policy {
            RouterPolicy::Fcfs => self.queue.push_back((req, self.round)),
            RouterPolicy::Sjf => {
                let pos = self
                    .queue
                    .iter()
                    .position(|(r, _)| r.max_new_tokens > req.max_new_tokens)
                    .unwrap_or(self.queue.len());
                self.queue.insert(pos, (req, self.round));
            }
        }
        None
    }

    /// Admit a request; `false` = backpressure (queue full, request
    /// dropped — prefer [`Self::try_submit`] to keep it).
    pub fn submit(&mut self, req: Request) -> bool {
        self.try_submit(req).is_none()
    }

    /// Take up to `n` requests for the next admission. Counts one aging
    /// round and force-promotes starved requests first (SJF only).
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        self.round += 1;
        if self.policy == RouterPolicy::Sjf && self.aging_rounds > 0 {
            self.promote_starved();
        }
        let k = n.min(self.queue.len());
        self.queue.drain(..k).map(|(r, _)| r).collect()
    }

    /// Move every request that has waited `aging_rounds` rounds to the
    /// front, ahead of younger entries, as a stable partition — the
    /// starved requests keep their current relative order whether or
    /// not the reorder actually runs. No-op (and no `promoted` count)
    /// when the starved set already leads the queue, so the counter
    /// records reorders that moved requests past younger work.
    fn promote_starved(&mut self) {
        let cutoff = self.round.saturating_sub(self.aging_rounds as u64);
        let starved = self.queue.iter().filter(|(_, at)| *at < cutoff).count();
        if starved == 0 || self.queue.iter().take(starved).all(|(_, at)| *at < cutoff) {
            return;
        }
        let mut aged: Vec<(Request, u64)> = Vec::with_capacity(starved);
        let mut rest: Vec<(Request, u64)> = Vec::with_capacity(self.queue.len() - starved);
        for entry in self.queue.drain(..) {
            if entry.1 < cutoff {
                aged.push(entry);
            } else {
                rest.push(entry);
            }
        }
        self.promoted += aged.len();
        self.queue.extend(aged);
        self.queue.extend(rest);
    }

    /// Borrow the next up-to-`n` requests without dequeuing them (the
    /// adaptive consult inspects joiners before committing to a plan).
    pub fn peek(&self, n: usize) -> Vec<&Request> {
        self.queue.iter().take(n).map(|(r, _)| r).collect()
    }

    /// Whether a request with this id is still queued.
    pub fn contains(&self, id: u64) -> bool {
        self.queue.iter().any(|(r, _)| r.id == id)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, gen: usize) -> Request {
        Request::new(id, vec![1, 2, 3], gen)
    }

    #[test]
    fn fcfs_preserves_order() {
        let mut r = Router::new(10, RouterPolicy::Fcfs);
        for i in 0..5 {
            assert!(r.submit(req(i, 10)));
        }
        let batch = r.take(3);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(r.pending(), 2);
        assert!(r.contains(3));
        assert!(!r.contains(0));
    }

    #[test]
    fn sjf_orders_by_generation_length() {
        let mut r = Router::new(10, RouterPolicy::Sjf);
        r.submit(req(0, 100));
        r.submit(req(1, 10));
        r.submit(req(2, 50));
        assert_eq!(r.peek(2).iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        let batch = r.take(3);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 0]);
    }

    #[test]
    fn backpressure_on_full_queue() {
        let mut r = Router::new(2, RouterPolicy::Fcfs);
        assert!(r.submit(req(0, 1)));
        assert!(r.submit(req(1, 1)));
        let back = r.try_submit(req(2, 1));
        assert_eq!(back.map(|b| b.id), Some(2), "rejected request must be returned");
        assert_eq!(r.rejected, 1);
        assert_eq!(r.admitted, 2);
    }

    #[test]
    fn sjf_aging_bounds_starvation() {
        // A long job under a steady stream of short ones: pure SJF
        // never serves it; with aging N it must reach the front within
        // N take rounds and be served on the next one.
        let aging = 4usize;
        let mut r = Router::new(64, RouterPolicy::Sjf).with_aging(aging);
        r.submit(req(1000, 500)); // the starving long request
        let mut served_at = None;
        for round in 0..3 * aging as u64 {
            // Two fresh short jobs per round keep the front crowded.
            r.submit(req(round * 2, 1));
            r.submit(req(round * 2 + 1, 1));
            let got = r.take(1);
            if got[0].id == 1000 {
                served_at = Some(round);
                break;
            }
        }
        let served_at = served_at.expect("aging never promoted the long request");
        assert!(
            served_at <= aging as u64 + 1,
            "starvation bound violated: served at round {served_at}"
        );
        assert!(r.promoted >= 1);
    }

    #[test]
    fn aging_promotion_is_stable_and_front_loaded() {
        let mut r = Router::new(64, RouterPolicy::Sjf).with_aging(2);
        r.submit(req(100, 900));
        r.submit(req(101, 800));
        // Age three rounds, feeding one fresh short job per round so
        // the front stays crowded with younger work.
        for i in 0..3 {
            r.take(0);
            r.submit(req(i, 1));
        }
        // Both longs are past the aging bound: the next take must put
        // them first, in their SJF order (101 before 100), ahead of
        // every younger short job.
        let got = r.take(4);
        let ids: Vec<u64> = got.iter().map(|r| r.id).collect();
        assert!(r.promoted >= 2, "no promotion recorded");
        assert_eq!(ids[..2], [101, 100], "starved requests must lead: {ids:?}");
    }

    #[test]
    fn fcfs_never_promotes() {
        let mut r = Router::new(8, RouterPolicy::Fcfs).with_aging(1);
        for i in 0..4 {
            r.submit(req(i, 100 - i as usize));
        }
        for _ in 0..4 {
            r.take(0);
        }
        assert_eq!(r.promoted, 0);
        assert_eq!(r.take(4).iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }
}
