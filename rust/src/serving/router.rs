//! Request router: admission, queueing, and batch-slot assignment.
//!
//! Modeled on the vLLM router's role: requests land in a bounded queue
//! (backpressure by rejection when full — the engine turns rejection
//! into drain-based backpressure), and the scheduler takes them in
//! arrival order or shortest-job-first.
//!
//! **SJF aging.** Pure SJF starves long requests under a steady stream
//! of short ones — fatal for the streaming engine, whose admission runs
//! every iteration. The router therefore tracks, per queued request,
//! how many `take` rounds it has waited; the `take` on which a request
//! has waited **exactly** `aging_rounds` rounds force-promotes it to
//! the front of the queue (stably — starved requests keep their
//! relative order). Promotion is **sticky**: promoted entries form a
//! front region that SJF insertion never places fresh work into, so a
//! promoted request is never re-passed (and never re-promoted) by
//! younger short jobs. The wait of any request is therefore bounded by
//! `aging_rounds` rounds plus the promoted set ahead of it at
//! promotion time — and that bound is exact when the promoted set is
//! empty (`sjf_aging_bounds_starvation`).

use super::Request;
use std::collections::VecDeque;

/// Default `take` rounds before a starved request is force-promoted.
pub const DEFAULT_AGING_ROUNDS: usize = 16;

/// Queue discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// First come, first served.
    Fcfs,
    /// Shortest (requested generation) job first — reduces p50 at some
    /// tail cost; aging bounds the tail (see module docs).
    Sjf,
}

/// Bounded admission queue.
#[derive(Debug)]
pub struct Router {
    /// Queued requests with the `round` they were enqueued at.
    queue: VecDeque<(Request, u64)>,
    pub capacity: usize,
    pub policy: RouterPolicy,
    pub rejected: usize,
    pub admitted: usize,
    /// SJF starvation bound in `take` rounds (0 disables promotion).
    pub aging_rounds: usize,
    /// Distinct requests force-promoted. Promotion is sticky — once in
    /// the front region an entry is never re-promoted, so this counts
    /// requests, not reorder events.
    pub promoted: usize,
    /// Leading queue entries that were force-promoted: a sticky front
    /// region that SJF insertion skips, so fresh short jobs can never
    /// slip ahead of already-promoted starved work.
    promoted_front: usize,
    round: u64,
}

impl Router {
    pub fn new(capacity: usize, policy: RouterPolicy) -> Router {
        Router {
            queue: VecDeque::new(),
            capacity,
            policy,
            rejected: 0,
            admitted: 0,
            aging_rounds: DEFAULT_AGING_ROUNDS,
            promoted: 0,
            promoted_front: 0,
            round: 0,
        }
    }

    /// Override the SJF aging bound (0 disables promotion).
    pub fn with_aging(mut self, rounds: usize) -> Router {
        self.aging_rounds = rounds;
        self
    }

    /// Admit a request; on backpressure (queue full) the request is
    /// handed back to the caller instead of being dropped.
    pub fn try_submit(&mut self, req: Request) -> Option<Request> {
        if self.queue.len() >= self.capacity {
            self.rejected += 1;
            return Some(req);
        }
        self.admitted += 1;
        match self.policy {
            RouterPolicy::Fcfs => self.queue.push_back((req, self.round)),
            RouterPolicy::Sjf => {
                // SJF-insert behind the promoted front region: fresh
                // short jobs never slip ahead of force-promoted work.
                let pos = self.promoted_front
                    + self
                        .queue
                        .iter()
                        .skip(self.promoted_front)
                        .position(|(r, _)| r.max_new_tokens > req.max_new_tokens)
                        .unwrap_or(self.queue.len() - self.promoted_front);
                self.queue.insert(pos, (req, self.round));
            }
        }
        None
    }

    /// Admit a request; `false` = backpressure (queue full, request
    /// dropped — prefer [`Self::try_submit`] to keep it).
    pub fn submit(&mut self, req: Request) -> bool {
        self.try_submit(req).is_none()
    }

    /// Take up to `n` requests for the next admission. Counts one aging
    /// round and force-promotes starved requests first (SJF only).
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        self.round += 1;
        if self.policy == RouterPolicy::Sjf && self.aging_rounds > 0 {
            self.promote_starved();
        }
        let k = n.min(self.queue.len());
        self.promoted_front = self.promoted_front.saturating_sub(k);
        self.queue.drain(..k).map(|(r, _)| r).collect()
    }

    /// Append every not-yet-promoted request that has waited
    /// `aging_rounds` rounds to the sticky promoted front region, as a
    /// stable partition — newly starved requests keep their current
    /// relative order behind the earlier-promoted ones. A request
    /// enqueued at round `R` is promoted on the take of round
    /// `R + aging_rounds` (it has then waited exactly `aging_rounds`
    /// rounds); entries already inside the front region are never
    /// rescanned, so each request is promoted (and counted) at most
    /// once.
    fn promote_starved(&mut self) {
        let Some(cutoff) = self.round.checked_sub(self.aging_rounds as u64) else {
            return; // no request can have waited `aging_rounds` yet
        };
        let starved = self
            .queue
            .iter()
            .skip(self.promoted_front)
            .filter(|(_, at)| *at <= cutoff)
            .count();
        if starved == 0 {
            return;
        }
        let mut aged: Vec<(Request, u64)> = Vec::with_capacity(starved);
        let mut rest: Vec<(Request, u64)> =
            Vec::with_capacity(self.queue.len() - self.promoted_front - starved);
        let tail: Vec<(Request, u64)> = self.queue.drain(self.promoted_front..).collect();
        for entry in tail {
            if entry.1 <= cutoff {
                aged.push(entry);
            } else {
                rest.push(entry);
            }
        }
        self.promoted += aged.len();
        self.promoted_front += aged.len();
        self.queue.extend(aged);
        self.queue.extend(rest);
    }

    /// Borrow the next up-to-`n` requests without dequeuing them (the
    /// adaptive consult inspects joiners before committing to a plan).
    pub fn peek(&self, n: usize) -> Vec<&Request> {
        self.queue.iter().take(n).map(|(r, _)| r).collect()
    }

    /// Whether a request with this id is still queued.
    pub fn contains(&self, id: u64) -> bool {
        self.queue.iter().any(|(r, _)| r.id == id)
    }

    /// Remove a queued request by id (cancellation). Keeps the sticky
    /// promoted front region consistent when the removed entry was
    /// inside it.
    pub fn remove(&mut self, id: u64) -> Option<Request> {
        let idx = self.queue.iter().position(|(r, _)| r.id == id)?;
        if idx < self.promoted_front {
            self.promoted_front -= 1;
        }
        self.queue.remove(idx).map(|(r, _)| r)
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, gen: usize) -> Request {
        Request::new(id, vec![1, 2, 3], gen)
    }

    #[test]
    fn fcfs_preserves_order() {
        let mut r = Router::new(10, RouterPolicy::Fcfs);
        for i in 0..5 {
            assert!(r.submit(req(i, 10)));
        }
        let batch = r.take(3);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(r.pending(), 2);
        assert!(r.contains(3));
        assert!(!r.contains(0));
    }

    #[test]
    fn sjf_orders_by_generation_length() {
        let mut r = Router::new(10, RouterPolicy::Sjf);
        r.submit(req(0, 100));
        r.submit(req(1, 10));
        r.submit(req(2, 50));
        assert_eq!(r.peek(2).iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2]);
        let batch = r.take(3);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 0]);
    }

    #[test]
    fn backpressure_on_full_queue() {
        let mut r = Router::new(2, RouterPolicy::Fcfs);
        assert!(r.submit(req(0, 1)));
        assert!(r.submit(req(1, 1)));
        let back = r.try_submit(req(2, 1));
        assert_eq!(back.map(|b| b.id), Some(2), "rejected request must be returned");
        assert_eq!(r.rejected, 1);
        assert_eq!(r.admitted, 2);
    }

    #[test]
    fn sjf_aging_bounds_starvation() {
        // A long job under a steady stream of short ones: pure SJF
        // never serves it; with aging N — and no other starved request
        // ahead of it — it must be served on EXACTLY the Nth take
        // round (the promoting take drains the front it was just moved
        // to). One fresh short job per round keeps the SJF front
        // crowded with younger work the whole time.
        let aging = 4usize;
        let mut r = Router::new(64, RouterPolicy::Sjf).with_aging(aging);
        r.submit(req(1000, 500)); // the starving long request, round 0
        let mut served_at = None;
        for round in 1..=3 * aging as u64 {
            // The fresh short job SJF-inserts ahead of the long one.
            r.submit(req(round, 1));
            let got = r.take(1);
            if got[0].id == 1000 {
                served_at = Some(round);
                break;
            }
        }
        assert_eq!(
            served_at,
            Some(aging as u64),
            "exact starvation bound violated (promoted {})",
            r.promoted
        );
        assert_eq!(r.promoted, 1);
    }

    #[test]
    fn promotion_is_sticky_against_fresh_short_jobs() {
        // Once force-promoted, a starved request leads the queue even
        // as younger short jobs keep arriving: SJF insertion skips the
        // promoted front region, and later rounds never re-promote.
        let mut r = Router::new(16, RouterPolicy::Sjf).with_aging(2);
        r.submit(req(7, 400));
        r.take(0); // round 1: not yet starved
        r.take(0); // round 2: waited exactly `aging` → promoted
        assert_eq!(r.promoted, 1);
        r.submit(req(0, 1));
        r.submit(req(1, 1));
        assert_eq!(
            r.peek(1)[0].id,
            7,
            "fresh short jobs SJF-inserted ahead of promoted work"
        );
        r.take(0); // another round: must not count a re-promotion
        assert_eq!(r.promoted, 1, "promotion re-counted");
        assert_eq!(
            r.take(3).iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![7, 0, 1],
            "promoted front region must drain first"
        );
    }

    #[test]
    fn aging_promotion_is_stable_and_front_loaded() {
        let mut r = Router::new(64, RouterPolicy::Sjf).with_aging(2);
        r.submit(req(100, 900));
        r.submit(req(101, 800));
        // Age three rounds, feeding one fresh short job per round so
        // the front stays crowded with younger work.
        for i in 0..3 {
            r.take(0);
            r.submit(req(i, 1));
        }
        // Both longs are past the aging bound: the next take must put
        // them first, in their SJF order (101 before 100), ahead of
        // every younger short job.
        let got = r.take(4);
        let ids: Vec<u64> = got.iter().map(|r| r.id).collect();
        assert!(r.promoted >= 2, "no promotion recorded");
        assert_eq!(ids[..2], [101, 100], "starved requests must lead: {ids:?}");
    }

    #[test]
    fn remove_keeps_promoted_front_consistent() {
        let mut r = Router::new(16, RouterPolicy::Sjf).with_aging(2);
        r.submit(req(7, 400));
        r.take(0); // round 1
        r.take(0); // round 2: promoted into the front region
        assert_eq!(r.promoted, 1);
        r.submit(req(0, 1));
        assert_eq!(r.remove(7).map(|q| q.id), Some(7), "queued request removable");
        assert!(r.remove(7).is_none(), "second removal finds nothing");
        // The front region shrank with the removal: the short job
        // leads and fresh SJF inserts order normally behind it.
        r.submit(req(1, 500));
        assert_eq!(r.take(2).iter().map(|q| q.id).collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn fcfs_never_promotes() {
        let mut r = Router::new(8, RouterPolicy::Fcfs).with_aging(1);
        for i in 0..4 {
            r.submit(req(i, 100 - i as usize));
        }
        for _ in 0..4 {
            r.take(0);
        }
        assert_eq!(r.promoted, 0);
        assert_eq!(r.take(4).iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
    }
}
