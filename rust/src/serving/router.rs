//! Request router: admission, queueing, and batch-slot assignment.
//!
//! Modeled on the vLLM router's role: requests land in a bounded FIFO
//! (backpressure by rejection when full), and the batcher drains them
//! in arrival order or shortest-job-first.

use super::Request;
use std::collections::VecDeque;

/// Queue discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// First come, first served.
    Fcfs,
    /// Shortest (requested generation) job first — reduces p50 at some
    /// tail cost.
    Sjf,
}

/// Bounded admission queue.
#[derive(Debug)]
pub struct Router {
    queue: VecDeque<Request>,
    pub capacity: usize,
    pub policy: RouterPolicy,
    pub rejected: usize,
    pub admitted: usize,
}

impl Router {
    pub fn new(capacity: usize, policy: RouterPolicy) -> Router {
        Router { queue: VecDeque::new(), capacity, policy, rejected: 0, admitted: 0 }
    }

    /// Admit a request; `false` = backpressure (queue full).
    pub fn submit(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.capacity {
            self.rejected += 1;
            return false;
        }
        self.admitted += 1;
        match self.policy {
            RouterPolicy::Fcfs => self.queue.push_back(req),
            RouterPolicy::Sjf => {
                let pos = self
                    .queue
                    .iter()
                    .position(|r| r.max_new_tokens > req.max_new_tokens)
                    .unwrap_or(self.queue.len());
                self.queue.insert(pos, req);
            }
        }
        true
    }

    /// Take up to `n` requests for the next batch.
    pub fn take(&mut self, n: usize) -> Vec<Request> {
        let k = n.min(self.queue.len());
        self.queue.drain(..k).collect()
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, gen: usize) -> Request {
        Request::new(id, vec![1, 2, 3], gen)
    }

    #[test]
    fn fcfs_preserves_order() {
        let mut r = Router::new(10, RouterPolicy::Fcfs);
        for i in 0..5 {
            assert!(r.submit(req(i, 10)));
        }
        let batch = r.take(3);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2]);
        assert_eq!(r.pending(), 2);
    }

    #[test]
    fn sjf_orders_by_generation_length() {
        let mut r = Router::new(10, RouterPolicy::Sjf);
        r.submit(req(0, 100));
        r.submit(req(1, 10));
        r.submit(req(2, 50));
        let batch = r.take(3);
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 0]);
    }

    #[test]
    fn backpressure_on_full_queue() {
        let mut r = Router::new(2, RouterPolicy::Fcfs);
        assert!(r.submit(req(0, 1)));
        assert!(r.submit(req(1, 1)));
        assert!(!r.submit(req(2, 1)));
        assert_eq!(r.rejected, 1);
        assert_eq!(r.admitted, 2);
    }
}
