//! Continuous batcher: packs queued requests into the fixed artifact
//! batch, padding prompts to the artifact prompt length and retiring
//! finished sequences each decode step.
//!
//! The AOT artifacts fix (B, S): prompts shorter than S are left-padded
//! with token 0 (position masking comes free from causal attention +
//! greedy decode reading only the last position), and batches smaller
//! than B are padded with inert dummy sequences.

use super::Request;

/// One packed batch ready for prefill.
#[derive(Debug, Clone)]
pub struct Batch {
    /// The real requests occupying the first `live` slots.
    pub requests: Vec<Request>,
    /// Flattened [B, S] prompt tokens (padded).
    pub tokens: Vec<i32>,
    /// Per-slot remaining generation budget (0 for padding slots).
    pub remaining: Vec<usize>,
    pub batch: usize,
    pub prompt_len: usize,
}

impl Batch {
    /// Live (non-padding) slots.
    pub fn live(&self) -> usize {
        self.requests.len()
    }

    /// True when every live sequence has exhausted its budget.
    pub fn done(&self) -> bool {
        self.remaining.iter().all(|&r| r == 0)
    }

    /// Max decode steps this batch still needs.
    pub fn max_remaining(&self) -> usize {
        self.remaining.iter().cloned().max().unwrap_or(0)
    }
}

/// Packs requests into artifact-shaped batches.
#[derive(Debug, Clone)]
pub struct Batcher {
    pub batch: usize,
    pub prompt_len: usize,
    /// Decode-step budget cap per batch (bounded by the KV cache).
    pub max_new_tokens: usize,
}

impl Batcher {
    pub fn new(batch: usize, prompt_len: usize, max_new_tokens: usize) -> Batcher {
        Batcher { batch, prompt_len, max_new_tokens }
    }

    /// Pack ONE request: the left-padded prompt row (`[S]` tokens) and
    /// the capped generation budget. The shared primitive of the gang
    /// batch packer and the streaming engine's chunked slot prefill —
    /// one padding rule means a request's model inputs are identical
    /// under either scheduler (the bit-equivalence precondition).
    pub fn pack_one(&self, req: &Request) -> (Vec<i32>, usize) {
        let mut row = vec![0i32; self.prompt_len];
        let p = &req.prompt;
        // Left-pad: place the prompt tail-aligned so the last position
        // is the newest prompt token.
        let n = p.len().min(self.prompt_len);
        row[self.prompt_len - n..].copy_from_slice(&p[p.len() - n..]);
        (row, req.max_new_tokens.min(self.max_new_tokens))
    }

    /// Pack up to `batch` requests (fewer → padding slots).
    pub fn pack(&self, requests: Vec<Request>) -> Batch {
        assert!(!requests.is_empty(), "cannot pack an empty batch");
        assert!(requests.len() <= self.batch);
        let mut tokens = vec![0i32; self.batch * self.prompt_len];
        let mut remaining = vec![0usize; self.batch];
        for (slot, req) in requests.iter().enumerate() {
            let (row, budget) = self.pack_one(req);
            tokens[slot * self.prompt_len..(slot + 1) * self.prompt_len]
                .copy_from_slice(&row);
            remaining[slot] = budget;
        }
        Batch {
            requests,
            tokens,
            remaining,
            batch: self.batch,
            prompt_len: self.prompt_len,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, prompt_len: usize, gen: usize) -> Request {
        Request::new(id, (0..prompt_len as i32).map(|i| i + 1).collect(), gen)
    }

    #[test]
    fn pads_prompts_left() {
        let b = Batcher::new(2, 8, 16);
        let batch = b.pack(vec![req(0, 3, 4)]);
        // Slot 0: 5 zeros then 1,2,3.
        assert_eq!(&batch.tokens[..8], &[0, 0, 0, 0, 0, 1, 2, 3]);
        // Slot 1 is padding.
        assert_eq!(&batch.tokens[8..], &[0; 8]);
        assert_eq!(batch.remaining, vec![4, 0]);
        assert_eq!(batch.live(), 1);
    }

    #[test]
    fn truncates_long_prompts_keeping_tail() {
        let b = Batcher::new(1, 4, 16);
        let batch = b.pack(vec![req(0, 10, 1)]);
        assert_eq!(&batch.tokens[..], &[7, 8, 9, 10]);
    }

    #[test]
    fn caps_generation_budget() {
        let b = Batcher::new(1, 4, 8);
        let batch = b.pack(vec![req(0, 2, 100)]);
        assert_eq!(batch.remaining[0], 8);
        assert_eq!(batch.max_remaining(), 8);
        assert!(!batch.done());
    }

    #[test]
    #[should_panic(expected = "empty batch")]
    fn empty_batch_rejected() {
        Batcher::new(2, 4, 8).pack(vec![]);
    }

    #[test]
    fn pack_one_matches_batch_row() {
        let b = Batcher::new(2, 8, 16);
        let r = req(0, 3, 40);
        let (row, budget) = b.pack_one(&r);
        let batch = b.pack(vec![r]);
        assert_eq!(&batch.tokens[..8], &row[..]);
        assert_eq!(batch.remaining[0], budget);
        assert_eq!(budget, 16, "budget capped by the KV window");
    }
}
