//! The serving runtime: a long-lived session [`Engine`] running
//! **continuous batching** over the device-grid executor, with Python
//! never on the request path (the DeepSpeed-FastGen role in the paper's
//! evaluation).
//!
//! The public surface is the [`Engine`] facade ([`engine`] module):
//! build one from a [`ServeConfig`] (fixed hybrid plan or adaptive
//! policy, router policy, scheduling knobs), then drive it at iteration
//! granularity —
//!
//! - [`Engine::submit`] enqueues a [`Request`] (full queues
//!   backpressure by draining, never abort);
//! - [`Engine::step`] runs ONE Orca-style scheduler iteration: retire
//!   finished sequences, admit queued requests into the freed KV slots
//!   mid-decode (chunked prefill for the joiners), one decode step for
//!   the running set;
//! - [`Engine::poll`] / [`Engine::drain`] deliver tokens as sequences
//!   progress and finish;
//! - [`Engine::shutdown`] completes outstanding work and returns the
//!   [`ServeReport`].
//!
//! Plan adaptation happens at admission boundaries; expert-layout
//! switches reshard in-flight while attention-layout switches drain to
//! a safe point first (see the [`engine`] docs). The legacy
//! run-to-completion helpers — [`serve_workload`], [`serve_on`],
//! [`server::spawn_server`] — remain as deprecated thin wrappers that
//! run the engine core under [`Scheduling::Gang`] (also the only mode
//! the fixed-shape PJRT artifacts support).

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{Batch, Batcher};
pub use engine::{
    serve_with, serve_with_recorder, Engine, EngineBuilder, EngineError, EngineState, RequestId,
    RequestStatus, Scheduling, StepOutcome, SubmitError, MAX_FAULT_RETRIES,
};
pub use metrics::Metrics;
pub use router::{Router, RouterPolicy};
pub use server::{
    serve_on, serve_workload, AdaptiveServing, GangConfigError, ServeConfig, ServeReport,
};

use std::time::Instant;

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Prompt token ids (truncated/padded to the artifact prompt len).
    pub prompt: Vec<i32>,
    /// Tokens to generate.
    pub max_new_tokens: usize,
    /// Submission time.
    pub arrived: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        Request { id, prompt, max_new_tokens, arrived: Instant::now() }
    }
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Time from arrival to completion.
    pub latency: f64,
    /// Time from arrival to first generated token.
    pub ttft: f64,
}
