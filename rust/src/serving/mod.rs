//! The serving runtime: router → continuous batcher → engine, with
//! Python never on the request path (the DeepSpeed-FastGen role in the
//! paper's evaluation).
//!
//! Thread-based (`std::thread` + `mpsc`): clients submit
//! [`Request`]s through a [`ServerHandle`]; the server thread admits
//! them through the router, forms fixed-size batches (the AOT artifact
//! batch), runs prefill once per batch and decode steps until every
//! sequence finishes, and answers with per-request metrics.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{Batch, Batcher};
pub use metrics::Metrics;
pub use router::{Router, RouterPolicy};
pub use server::{serve_on, serve_workload, AdaptiveServing, ServeConfig, ServeReport};

use std::time::Instant;

/// A generation request.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: u64,
    /// Prompt token ids (truncated/padded to the artifact prompt len).
    pub prompt: Vec<i32>,
    /// Tokens to generate.
    pub max_new_tokens: usize,
    /// Submission time.
    pub arrived: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        Request { id, prompt, max_new_tokens, arrived: Instant::now() }
    }
}

/// A completed generation.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// Time from arrival to completion.
    pub latency: f64,
    /// Time from arrival to first generated token.
    pub ttft: f64,
}
