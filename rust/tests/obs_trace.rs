//! Tier-1 acceptance for the deterministic tracing & telemetry
//! subsystem (obs):
//!
//! - two seeded fixed-plan streaming runs of the same workload produce
//!   **byte-identical** canonical trace streams (wall-time payload
//!   fields stripped) — and the same holds with a `--fault-trace`
//!   crash in the middle, FaultDetected/Retry/DegradedReplan events
//!   included;
//! - an adaptive run records a `PlanConsult` audit event per admission
//!   boundary, cold-starting with an `adopt` decision;
//! - a `force_plans()` switch is traced as exactly one
//!   `Switch{mode:"forced"}` event with the correct from/to plan
//!   labels;
//! - the shutdown report's metrics registry agrees with the raw
//!   counters, wall time is finalized exactly once, and throughput is
//!   non-zero on any completed run;
//! - `summarize_lines` folds a trace back into per-module shares that
//!   are normalized and complete.
//!
//! Everything runs artifact-free on the host grid engine.

use hap::model::{FaultPlan, ModelExecutor, ShardPlan, WeightStore};
use hap::obs::{canonical_stream, events_to_jsonl, EventKind, MetricValue, Recorder, TraceEvent};
use hap::runtime::TinyModelMeta;
use hap::serving::{serve_with_recorder, Engine, Request, Scheduling, ServeConfig};
use hap::strategy::{AttnStrategy, ExpertStrategy};
use hap::util::json::Json;
use hap::util::rng::Rng;

fn meta() -> TinyModelMeta {
    TinyModelMeta::host_demo()
}

fn workload(m: &TinyModelMeta, n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n as u64)
        .map(|id| {
            let len = rng.range(m.prefill_len / 2, m.prefill_len);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(m.vocab) as i32).collect();
            let gen = rng.range(2, 8);
            Request::new(id, prompt, gen)
        })
        .collect()
}

fn kind_count(events: &[TraceEvent], name: &str) -> usize {
    events.iter().filter(|e| e.kind.name() == name).count()
}

/// One fixed-plan streaming run with an enabled recorder, returning
/// the recorded events.
fn traced_run(seed: u64) -> Vec<TraceEvent> {
    let m = meta();
    let weights = WeightStore::synthetic(&m, 11);
    let mut exec = ModelExecutor::host(weights);
    let mut config = ServeConfig::hap_transition(4);
    config.prefill_chunk = 8;
    let report = serve_with_recorder(
        &mut exec,
        &config,
        Scheduling::Streaming,
        workload(&m, 8, seed),
        Recorder::new(),
    )
    .unwrap();
    assert_eq!(report.metrics.requests_completed, 8);
    report.trace
}

#[test]
fn fixed_plan_streaming_trace_is_deterministic() {
    let a = traced_run(5);
    let b = traced_run(5);
    assert!(!a.is_empty(), "enabled recorder produced no events");
    for kind in ["Admit", "PrefillChunk", "DecodeStep", "Retire"] {
        assert!(kind_count(&a, kind) > 0, "trace is missing {kind} events");
    }
    // The envelope is ordered by the deterministic iteration clock:
    // seq strictly increases, iter never goes backwards.
    for w in a.windows(2) {
        assert!(w[1].seq > w[0].seq, "seq not strictly increasing");
        assert!(w[1].iter >= w[0].iter, "iteration clock went backwards");
    }
    // Byte-identical canonical streams: same events, same order, same
    // deterministic payloads — only the wall-time fields may differ.
    let ca = canonical_stream(&events_to_jsonl(&a)).unwrap();
    let cb = canonical_stream(&events_to_jsonl(&b)).unwrap();
    assert_eq!(ca, cb, "two identical seeded runs diverged after stripping wall fields");
}

#[test]
fn fault_crash_trace_is_deterministic_and_records_recovery() {
    let run = || {
        let m = meta();
        let mut engine = Engine::builder(ServeConfig::tp(4))
            .fault_plan(FaultPlan::parse_trace("crash@6").unwrap())
            .recorder(Recorder::new())
            .build_host(WeightStore::synthetic(&m, 42));
        for req in workload(&m, 8, 5) {
            engine.submit(req).unwrap();
        }
        engine.run_to_completion().unwrap();
        engine.shutdown().unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.metrics.requests_completed, 8);
    assert!(kind_count(&a.trace, "FaultDetected") >= 1, "crash not traced");
    assert!(kind_count(&a.trace, "DegradedReplan") >= 1, "degraded re-plan not traced");
    // The fault-recovery path (detection, degrade, requeue, replay) is
    // iteration-clocked, so even the crashed run's stream is
    // reproducible byte for byte.
    let ca = canonical_stream(&events_to_jsonl(&a.trace)).unwrap();
    let cb = canonical_stream(&events_to_jsonl(&b.trace)).unwrap();
    assert_eq!(ca, cb, "fault-recovery trace diverged across identical seeded runs");
}

#[test]
fn adaptive_run_emits_plan_consult_audit_events() {
    let m = meta();
    let mut engine = Engine::builder(ServeConfig::adaptive(4))
        .recorder(Recorder::new())
        .build_host(WeightStore::synthetic(&m, 7));
    for req in workload(&m, 8, 3) {
        engine.submit(req).unwrap();
    }
    let report = engine.shutdown().unwrap();
    assert_eq!(report.metrics.requests_completed, 8);
    let consults: Vec<&hap::obs::PlanConsult> = report
        .trace
        .iter()
        .filter_map(|e| match &e.kind {
            EventKind::PlanConsult(c) => Some(c),
            _ => None,
        })
        .collect();
    assert!(!consults.is_empty(), "adaptive run recorded no PlanConsult events");
    let first = consults[0];
    assert_eq!(first.decision, "adopt", "cold start must adopt");
    assert!(first.active.is_none(), "cold start has no active plan");
    assert!(!first.cached, "cold start cannot be a cache hit");
    assert!(first.predicted_candidate_s > 0.0);
    for c in &consults {
        assert!(
            matches!(c.decision.as_str(), "adopt" | "stay" | "switch"),
            "unknown decision '{}'",
            c.decision
        );
        assert!(c.key.starts_with("ctx"), "malformed traffic key '{}'", c.key);
    }
}

#[test]
fn forced_switch_is_traced_and_suppresses_the_next_measured_window() {
    let m = meta();
    let mut engine = Engine::builder(ServeConfig::tp(4))
        .recorder(Recorder::new())
        .build_host(WeightStore::synthetic(&m, 13));
    for req in workload(&m, 6, 9) {
        engine.submit(req).unwrap();
    }
    // Start the session under TP4, then force an expert-only switch
    // (same attention layout → applied immediately via reshard).
    engine.step().unwrap();
    let forced_prefill = ShardPlan::new(AttnStrategy::new(4, 1), ExpertStrategy::new(1, 4));
    let forced_decode = ShardPlan::tp(4);
    engine.force_plans(forced_prefill, forced_decode).unwrap();
    engine.run_to_completion().unwrap();
    let report = engine.shutdown().unwrap();
    assert_eq!(report.metrics.requests_completed, 6);

    let forced: Vec<(&String, &String)> = report
        .trace
        .iter()
        .filter_map(|e| match &e.kind {
            EventKind::Switch { from, to, mode } if *mode == "forced" => Some((from, to)),
            _ => None,
        })
        .collect();
    assert_eq!(forced.len(), 1, "exactly one forced switch expected");
    let (from, to) = forced[0];
    assert_eq!(from, &ShardPlan::tp(4).label(), "forced switch 'from' label wrong");
    assert!(
        to.contains(&forced_prefill.label()) && to.contains(&forced_decode.label()),
        "forced switch 'to' label wrong: {to}"
    );

    // Satellite regression: a completed run can never report zero
    // throughput — wall time is finalized exactly once at shutdown.
    assert!(report.metrics.wall_time > 0.0);
    assert!(report.metrics.throughput() > 0.0, "completed run reported 0 tok/s");
}

#[test]
fn report_registry_agrees_with_raw_metrics() {
    let trace = traced_run(5);
    // Re-run to get the report (traced_run only returns events).
    let m = meta();
    let mut exec = ModelExecutor::host(WeightStore::synthetic(&m, 11));
    let mut config = ServeConfig::hap_transition(4);
    config.prefill_chunk = 8;
    let report = serve_with_recorder(
        &mut exec,
        &config,
        Scheduling::Streaming,
        workload(&m, 8, 5),
        Recorder::new(),
    )
    .unwrap();
    match report.telemetry.get("requests_completed") {
        Some(MetricValue::Counter(n)) => {
            assert_eq!(*n, report.metrics.requests_completed as u64)
        }
        other => panic!("requests_completed missing from registry: {other:?}"),
    }
    match report.telemetry.get("decode_steps") {
        Some(MetricValue::Counter(n)) => assert_eq!(*n, report.metrics.decode_steps as u64),
        other => panic!("decode_steps missing from registry: {other:?}"),
    }
    // The registry exports cleanly in both formats.
    let json = report.telemetry.to_json().to_string_pretty();
    Json::parse(&json).expect("registry JSON must parse");
    let prom = report.telemetry.to_prometheus();
    assert!(prom.contains("hap_requests_completed"), "prometheus export missing counter");
    // And the trace from the first identical run matches this one.
    assert_eq!(
        canonical_stream(&events_to_jsonl(&trace)).unwrap(),
        canonical_stream(&events_to_jsonl(&report.trace)).unwrap(),
    );
}

#[test]
fn summarize_folds_a_trace_into_normalized_module_shares() {
    let events = traced_run(5);
    let jsonl = events_to_jsonl(&events);
    let lines: Vec<Json> = jsonl.lines().map(|l| Json::parse(l).unwrap()).collect();
    let summary = hap::obs::summarize_lines(&lines);
    assert!(summary.iterations > 0);
    for kind in ["Admit", "PrefillChunk", "DecodeStep", "Retire"] {
        let counted = summary
            .counts
            .iter()
            .find(|(k, _)| k == kind)
            .map(|(_, c)| *c)
            .unwrap_or(0);
        assert_eq!(counted, kind_count(&events, kind), "summary miscounted {kind}");
    }
    let shares = summary.shares();
    assert_eq!(shares.len(), 4, "four module buckets expected");
    let total: f64 = shares.iter().map(|(_, s)| s).sum();
    assert!(
        (total - 1.0).abs() < 1e-9 || total == 0.0,
        "module shares must normalize (got {total})"
    );
    let rendered = summary.render();
    assert!(rendered.contains("attention"), "render missing module breakdown: {rendered}");
}
