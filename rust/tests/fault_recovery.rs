//! Tier-1 acceptance for fault-tolerant serving (ISSUE 6):
//!
//! - a deterministic device **crash** mid-run triggers a degraded
//!   re-plan onto the surviving power-of-two grid, and every recovered
//!   request's tokens are **bit-identical** to the same workload run
//!   on an unfaulted grid of the degraded size (replay-from-prompt
//!   recovery, row-independent kernels);
//! - **transient** faults and bounded **stalls** are absorbed by the
//!   retry/backoff path: zero requeues, zero re-plans, tokens
//!   bit-identical to an unfaulted run;
//! - **total grid loss** drains every request as `Failed{reason}` and
//!   latches the engine: `step()` keeps returning the fatal error;
//! - `cancel()` removes one request wherever it lives while its peers'
//!   token streams stay bit-identical;
//! - `try_submit()` reports queue exhaustion as a typed
//!   [`SubmitError::QueueFull`] with a deterministic retry hint
//!   instead of running drain iterations.
//!
//! Everything runs artifact-free on the host grid engine with seeded
//! fault schedules — no wall clocks, no runtime randomness.

use hap::model::{FaultPlan, WeightStore};
use hap::runtime::TinyModelMeta;
use hap::serving::{
    Engine, EngineState, Request, RequestStatus, ServeConfig, ServeReport, SubmitError,
};
use hap::util::rng::Rng;

fn meta() -> TinyModelMeta {
    TinyModelMeta::host_demo()
}

fn weights(seed: u64) -> WeightStore {
    WeightStore::synthetic(&meta(), seed)
}

fn mixed_workload(m: &TinyModelMeta, n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n as u64)
        .map(|id| {
            let len = rng.range(m.prefill_len / 2, m.prefill_len);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(m.vocab) as i32).collect();
            let gen = rng.range(2, 8);
            Request::new(id, prompt, gen)
        })
        .collect()
}

fn sorted_tokens(report: &ServeReport) -> Vec<(u64, Vec<i32>)> {
    let mut t: Vec<(u64, Vec<i32>)> =
        report.responses.iter().map(|r| (r.id, r.tokens.clone())).collect();
    t.sort();
    t
}

#[test]
fn crash_recovery_is_bit_identical_to_unfaulted_degraded_grid() {
    let m = meta();
    let n = 8usize;

    // Reference: the same workload on an unfaulted 2-device grid — the
    // size the 4-device engine degrades to after losing one device.
    let mut reference = Engine::builder(ServeConfig::tp(2)).build_host(weights(42));
    for req in mixed_workload(&m, n, 5) {
        reference.submit(req).unwrap();
    }
    let reference = reference.shutdown().unwrap();
    assert_eq!(reference.metrics.requests_completed, n);

    // Faulted: device 0 crashes at fault-clock iteration 6, with the
    // first admission wave in flight.
    let mut engine = Engine::builder(ServeConfig::tp(4))
        .fault_plan(FaultPlan::parse_trace("crash@6").unwrap())
        .build_host(weights(42));
    for req in mixed_workload(&m, n, 5) {
        engine.submit(req).unwrap();
    }
    engine.run_to_completion().unwrap();

    assert_eq!(
        engine.state(),
        EngineState::Degraded { devices: 2 },
        "confirmed crash must shrink the grid to the surviving power of two"
    );
    assert!(!engine.recovered().is_empty(), "no in-flight request was recovered");
    let recovered = engine.recovered().to_vec();
    for id in &recovered {
        assert!(
            matches!(engine.poll(*id), RequestStatus::Finished(_)),
            "recovered request {id} did not finish"
        );
    }

    let report = engine.shutdown().unwrap();
    assert_eq!(report.metrics.requests_completed, n, "every request completes post-crash");
    assert_eq!(report.metrics.faults_detected, 1);
    assert_eq!(report.metrics.replans_degraded, 1);
    assert!(report.metrics.requests_recovered >= 1);
    assert_eq!(report.metrics.requests_recovered, recovered.len());
    assert_eq!(report.metrics.requests_failed, 0);

    // Replay-from-prompt recovery on row-independent kernels: tokens
    // must match the unfaulted degraded-size run exactly — for the
    // recovered requests AND the ones that completed before the crash.
    assert_eq!(
        sorted_tokens(&reference),
        sorted_tokens(&report),
        "crash recovery changed generated tokens"
    );
}

#[test]
fn transient_and_stall_faults_absorbed_by_retries_without_requeue() {
    let m = meta();
    let n = 6usize;

    let mut reference = Engine::builder(ServeConfig::tp(4)).build_host(weights(42));
    for req in mixed_workload(&m, n, 9) {
        reference.submit(req).unwrap();
    }
    let reference = reference.shutdown().unwrap();

    // transient2@5: the next two device-0 ops after iteration 5 fail;
    // stall2@4: device 0 stalls for iterations 4–5. Both recover
    // through the bounded backoff path — each burns exactly two
    // retries before the clock moves past the fault.
    for trace in ["transient2@5", "stall2@4"] {
        let mut engine = Engine::builder(ServeConfig::tp(4))
            .fault_plan(FaultPlan::parse_trace(trace).unwrap())
            .build_host(weights(42));
        for req in mixed_workload(&m, n, 9) {
            engine.submit(req).unwrap();
        }
        engine.run_to_completion().unwrap();
        assert_eq!(engine.state(), EngineState::Healthy, "{trace} must not degrade the grid");
        assert!(engine.recovered().is_empty(), "{trace} requeued requests");

        let report = engine.shutdown().unwrap();
        assert_eq!(report.metrics.requests_completed, n);
        assert_eq!(report.metrics.faults_detected, 1, "{trace}: one fault episode");
        assert_eq!(report.metrics.fault_retries, 2, "{trace}: two failed ops, two retries");
        assert_eq!(report.metrics.replans_degraded, 0, "{trace}");
        assert_eq!(report.metrics.requests_recovered, 0, "{trace}");
        assert_eq!(report.metrics.requests_failed, 0, "{trace}");
        assert_eq!(
            sorted_tokens(&reference),
            sorted_tokens(&report),
            "{trace}: retried ops diverged from the unfaulted run"
        );
    }
}

#[test]
fn total_grid_loss_fails_all_requests_and_latches() {
    let m = meta();
    // Lose every device in sequence: 4 → 2 → 1 → none. Events for
    // devices beyond each degraded grid are compacted away, so the
    // surviving schedule is crash d0, then crash d1 (of the 2-device
    // grid), then crash d0 (the last device).
    let mut engine = Engine::builder(ServeConfig::tp(4))
        .fault_plan(FaultPlan::parse_trace("crash@2@d0,crash@4@d1,crash@6@d0").unwrap())
        .build_host(weights(42));
    let ids: Vec<u64> = mixed_workload(&m, 4, 13)
        .into_iter()
        .map(|req| engine.submit(req).unwrap())
        .collect();

    let err = engine.run_to_completion().expect_err("total grid loss must surface an error");
    let msg = format!("{err:#}");
    assert!(msg.contains("engine failed"), "unexpected error: {msg}");

    assert_eq!(engine.state(), EngineState::Failed);
    for id in &ids {
        match engine.poll(*id) {
            RequestStatus::Failed { reason } => {
                assert!(!reason.is_empty(), "failed request {id} has no reason")
            }
            other => panic!("request {id} should have drained as Failed, got {other:?}"),
        }
    }
    // The failure latches: every subsequent step returns the same
    // fatal error instead of limping on.
    assert!(engine.step().is_err());
    assert!(engine.step().is_err());
}

#[test]
fn cancel_leaves_peer_tokens_bit_identical() {
    let m = meta();
    let n = 6usize;
    let victim = 2u64;
    // Explicit 6-token budgets: after two iterations every admitted
    // request is deterministically mid-decode, so the cancel hits a
    // live slot with populated KV.
    let workload = |m: &TinyModelMeta| -> Vec<Request> {
        (0..n as u64)
            .map(|id| {
                let len = 6 + id as usize;
                let prompt: Vec<i32> =
                    (0..len).map(|i| ((i as u64 * 7 + id * 13 + 3) % m.vocab as u64) as i32).collect();
                Request::new(id, prompt, 6)
            })
            .collect()
    };

    let mut reference = Engine::builder(ServeConfig::tp(4)).build_host(weights(42));
    for req in workload(&m) {
        reference.submit(req).unwrap();
    }
    let reference = reference.shutdown().unwrap();
    let reference_peers: Vec<(u64, Vec<i32>)> =
        sorted_tokens(&reference).into_iter().filter(|(id, _)| *id != victim).collect();

    let mut engine = Engine::builder(ServeConfig::tp(4)).build_host(weights(42));
    for req in workload(&m) {
        engine.submit(req).unwrap();
    }
    engine.step().unwrap();
    engine.step().unwrap();
    assert!(matches!(engine.poll(victim), RequestStatus::Running { .. }));
    let status = engine.cancel(victim).unwrap();
    assert!(matches!(status, RequestStatus::Cancelled), "got {status:?}");
    assert!(matches!(engine.poll(victim), RequestStatus::Cancelled));
    // Cancelling twice is a no-op that reports the current status.
    assert!(matches!(engine.cancel(victim).unwrap(), RequestStatus::Cancelled));

    let report = engine.shutdown().unwrap();
    assert_eq!(report.metrics.requests_completed, n - 1);
    assert!(
        report.responses.iter().all(|r| r.id != victim),
        "cancelled request still produced a response"
    );
    assert_eq!(
        reference_peers,
        sorted_tokens(&report),
        "cancelling one slot leaked into its peers' KV"
    );
}

#[test]
fn try_submit_reports_queue_full_with_deterministic_retry_hint() {
    let m = meta();
    let mut config = ServeConfig::tp(4);
    config.queue_capacity = 2;
    let mut engine = Engine::builder(config).build_host(weights(11));
    let prompt: Vec<i32> = (0..8).map(|i| (i * 3 + 1) % m.vocab as i32).collect();

    engine.try_submit(Request::new(0, prompt.clone(), 5)).unwrap();
    engine.try_submit(Request::new(1, prompt.clone(), 5)).unwrap();
    // Queue full with nothing running yet: the hint bottoms out at one
    // iteration (the admission step itself frees the queue).
    match engine.try_submit(Request::new(2, prompt.clone(), 5)) {
        Err(SubmitError::QueueFull { retry_after_iters }) => {
            assert_eq!(retry_after_iters, 1, "idle engine should hint one iteration")
        }
        other => panic!("expected QueueFull, got {other:?}"),
    }
    // submit()'s drain semantics are untouched: the same third request
    // goes through by running iterations instead of erroring.
    engine.submit(Request::new(2, prompt.clone(), 5)).unwrap();

    // With the batch decoding, the hint tracks the shortest remaining
    // generation among running slots — positive and bounded by the
    // per-request budget.
    engine.step().unwrap();
    for id in 3..10u64 {
        match engine.try_submit(Request::new(id, prompt.clone(), 5)) {
            Ok(_) => continue,
            Err(SubmitError::QueueFull { retry_after_iters }) => {
                assert!(
                    retry_after_iters >= 1 && retry_after_iters <= 5,
                    "hint {retry_after_iters} outside the running set's decode budget"
                );
                let shown = format!("{}", SubmitError::QueueFull { retry_after_iters });
                assert!(shown.contains("queue full"), "unhelpful error display: {shown}");
                engine.run_to_completion().unwrap();
                let report = engine.shutdown().unwrap();
                assert!(report.metrics.requests_completed >= 3);
                return;
            }
        }
    }
    panic!("queue of capacity 2 never filled");
}
