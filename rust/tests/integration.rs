//! Cross-module integration tests: planner ↔ engine ↔ transition ↔
//! simulation stack, over the paper's models, platforms, and scenarios.

use hap::config::{GpuSpec, MoEModelConfig, NodeConfig, Scenario};
use hap::engine::Engine;
use hap::planner::HapPlanner;
use hap::sim::LatencyModel;
use hap::strategy::{AttnStrategy, ExpertStrategy, SearchSpace};
use hap::transition::{TransitionMethod, TransitionModel};

/// The planner's predicted ordering should agree with the engine's
/// measured ordering for clearly separated strategy pairs (prediction
/// is useful iff it ranks correctly).
#[test]
fn predicted_ordering_matches_measured_ordering() {
    let model = MoEModelConfig::mixtral_8x7b();
    let node = NodeConfig::a6000x(4);
    let planner = HapPlanner::new(&model, &node);
    let engine = Engine::new(&model, &node);
    let sc = Scenario::long_constrained();

    let configs = [
        (AttnStrategy::new(4, 1), ExpertStrategy::new(4, 1)),
        (AttnStrategy::new(1, 4), ExpertStrategy::new(1, 4)),
        (AttnStrategy::new(1, 4), ExpertStrategy::new(4, 1)),
    ];
    let mut rows: Vec<(f64, f64)> = Vec::new();
    for (a, e) in &configs {
        let pred = planner.predict_fixed(&sc, a, e);
        let meas = engine.run_static(a, e, &sc, 3).total();
        rows.push((pred, meas));
    }
    // Pairwise ordering agreement for pairs separated by >15% measured.
    for i in 0..rows.len() {
        for j in 0..rows.len() {
            let (pi, mi) = rows[i];
            let (pj, mj) = rows[j];
            if mi < mj * 0.85 {
                assert!(
                    pi < pj,
                    "ordering disagreement: measured {mi:.3}<{mj:.3} but predicted {pi:.3}>={pj:.3}"
                );
            }
        }
    }
}

/// HAP's measured latency should never be meaningfully worse than the
/// measured TP baseline on any (model, node, scenario) triple — the
/// paper's "comparable or superior" claim, end to end.
#[test]
fn hap_measured_never_meaningfully_worse_than_tp() {
    for model in MoEModelConfig::paper_models() {
        for node in [NodeConfig::a6000x(4), NodeConfig::a100x(4)] {
            let planner = HapPlanner::new(&model, &node);
            let engine = Engine::new(&model, &node);
            for sc in Scenario::table2() {
                let plan = planner.plan(&sc, sc.generate).unwrap();
                let n = node.num_devices;
                let tp = engine
                    .run_static(&AttnStrategy::new(n, 1), &ExpertStrategy::new(n, 1), &sc, 1)
                    .total();
                let hap = engine.run_plan(&plan, &sc, 1).total();
                assert!(
                    hap <= tp * 1.08,
                    "{} {} on {}: HAP {hap:.3}s vs TP {tp:.3}s",
                    model.name,
                    sc.name,
                    node.label()
                );
            }
        }
    }
}

/// Paper IV-C3: long-context/constrained-output on PCIe is the
/// headline case — HAP must beat TP by a wide margin there.
#[test]
fn long_context_headline_speedup_on_pcie() {
    let model = MoEModelConfig::mixtral_8x7b();
    let node = NodeConfig::a6000x(4);
    let planner = HapPlanner::new(&model, &node);
    let engine = Engine::new(&model, &node);
    let sc = Scenario::long_constrained();
    let plan = planner.plan(&sc, sc.generate).unwrap();
    let tp = engine
        .run_static(&AttnStrategy::new(4, 1), &ExpertStrategy::new(4, 1), &sc, 1)
        .total();
    let hap = engine.run_plan(&plan, &sc, 1).total();
    let speedup = tp / hap;
    assert!(speedup > 1.2, "headline speedup too small: {speedup:.2}x ({plan})");
}

/// NVLink vs PCIe adaptivity: the chosen prefill configuration should
/// differ (or at least the PCIe win should exceed the NVLink win).
#[test]
fn interconnect_changes_the_decision_or_the_margin() {
    let model = MoEModelConfig::mixtral_8x7b();
    let sc = Scenario::long_constrained();
    let mut wins = Vec::new();
    for node in [NodeConfig::a6000x(4), NodeConfig::a100x(4)] {
        let planner = HapPlanner::new(&model, &node);
        let engine = Engine::new(&model, &node);
        let plan = planner.plan(&sc, sc.generate).unwrap();
        let n = node.num_devices;
        let tp = engine
            .run_static(&AttnStrategy::new(n, 1), &ExpertStrategy::new(n, 1), &sc, 1)
            .total();
        let hap = engine.run_plan(&plan, &sc, 1).total();
        wins.push(tp / hap);
    }
    assert!(
        wins[0] > wins[1] * 0.95,
        "PCIe win {:.2}x should generally exceed NVLink win {:.2}x",
        wins[0],
        wins[1]
    );
}

/// Transition model: eq. 6's minimum is honored for every (i, j) pair
/// in a real cost-table build.
#[test]
fn switching_matrix_respects_eq6_minimum() {
    let model = MoEModelConfig::mixtral_8x7b();
    let node = NodeConfig::a6000x(4);
    let planner = HapPlanner::new(&model, &node);
    let sc = Scenario::long_extended();
    let space = planner.search_space(&sc);
    let tables = planner.cost_tables(&space, &sc);
    for (i, row) in tables.switching.iter().enumerate() {
        for (j, cost) in row.iter().enumerate() {
            if i == j {
                assert_eq!(cost.method, TransitionMethod::None);
                assert_eq!(cost.overhead, 0.0);
            } else {
                assert!(cost.overhead <= cost.reshard + 1e-12);
                assert!(cost.overhead >= 0.0);
            }
        }
    }
}

/// The INT4-backup path should be chosen (and ~free) when a long
/// prefill hides the upload on a PCIe platform.
#[test]
fn int4_backup_free_under_long_prefill() {
    let model = MoEModelConfig::mixtral_8x7b();
    let gpu = GpuSpec::a6000();
    let lm = LatencyModel::train(&gpu, 1);
    let tm = TransitionModel::new(&model, &gpu);
    let c = tm.cost(&lm, &ExpertStrategy::new(1, 4), &ExpertStrategy::new(4, 1), 5.0);
    assert_eq!(c.method, TransitionMethod::Int4Backup);
    assert_eq!(c.overhead, 0.0);
}

/// Search spaces stay feasible and within expected sizes for all
/// paper configurations.
#[test]
fn search_spaces_feasible_for_all_paper_configs() {
    for model in MoEModelConfig::paper_models() {
        for node in [NodeConfig::a6000x(4), NodeConfig::a100x(4), NodeConfig::a100x(8)] {
            if model.name == "mixtral-8x7b" && node.gpu.mem_bytes < 40e9 {
                continue;
            }
            for sc in Scenario::table2() {
                let space = SearchSpace::enumerate(&model, &node, &sc);
                assert!(
                    space.is_feasible(),
                    "{} on {} {} infeasible",
                    model.name,
                    node.label(),
                    sc.name
                );
                let max_k = (node.num_devices as f64).log2() as usize + 1;
                assert!(space.k_a() <= max_k);
                assert!(space.k_e() <= max_k);
            }
        }
    }
}

/// 8×V100 (32 GB, PCIe) Fig 8(b) configuration end-to-end.
#[test]
fn fig8b_v100_plan_beats_tp() {
    let model = MoEModelConfig::mixtral_8x7b();
    let node = NodeConfig::v100x(8);
    let planner = HapPlanner::new(&model, &node);
    let engine = Engine::new(&model, &node);
    let sc = Scenario::fig8_v100();
    let plan = planner.plan(&sc, sc.generate).unwrap();
    let tp = engine
        .run_static(&AttnStrategy::new(8, 1), &ExpertStrategy::new(8, 1), &sc, 1)
        .total();
    let hap = engine.run_plan(&plan, &sc, 1).total();
    assert!(tp / hap > 1.1, "V100 speedup {:.2}x too small ({plan})", tp / hap);
}

/// Qwen models (many small experts, shared experts) plan successfully
/// and respect expert-count divisibility.
#[test]
fn qwen_plans_respect_divisibility() {
    let model = MoEModelConfig::qwen15_moe_a27b(); // 60 experts
    let node = NodeConfig::a100x(8);
    let planner = HapPlanner::new(&model, &node);
    let plan = planner.plan(&Scenario::short_constrained(), 64).unwrap();
    for e in [plan.expert_prefill, plan.expert_decode] {
        assert_eq!(model.num_experts % e.ep, 0, "EP {} doesn't divide 60", e.ep);
        assert_eq!(model.moe_inter_size % e.tp, 0);
    }
}
