//! Blocked/SIMD and fused-quant kernel equivalence sweeps (ISSUE 8).
//!
//! The blocked host kernels ([`hap::model::kernels`]) promise *bitwise*
//! equality with the scalar reference path (`kernels::reference`): the
//! packed layout changes traversal order, never the per-element
//! accumulation order. These property sweeps drive ragged shapes (rows,
//! cols, and reduction dims off the `NB = 16` panel size, GQA head
//! groups, top-k edges) through both paths and compare `to_bits`.
//! Built with `--features simd`, the same sweeps cover the explicit
//! lane kernels — the blocked path dispatches internally to the AVX2
//! 8-lane kernel when the host CPU reports the feature, SSE2 otherwise.
//!
//! The quantized path promises something weaker by design (int8/int4
//! round-tripping is lossy) but exact in a testable sense: the fused
//! dequant-matmul equals the reference matmul run on
//! `PackedQuant::dequantized()` bitwise, and on weights that sit
//! exactly on the quantization grid (so dequantization reproduces
//! every value), end-to-end quantized serving emits *identical greedy
//! tokens* to the f32 engine.

use hap::model::kernels::{
    self, reference, AttnWeights, ExpertWeights, HeadWeights, PackedRhs, NB, QUANT_GROUP,
};
use hap::model::{ModelExecutor, WeightStore};
use hap::prop_assert;
use hap::quant::QuantKind;
use hap::runtime::literal::HostTensor;
use hap::runtime::TinyModelMeta;
use hap::serving::{serve_on, Request, ServeConfig};
use hap::util::prop::check_default;
use hap::util::rng::Rng;

fn bits_eq(a: &[f32], b: &[f32]) -> bool {
    a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Draw a dimension that is deliberately often *not* a multiple of the
/// panel size: raw 1..=hi, snapped to a multiple of NB a quarter of the
/// time so exact-fit panels stay covered too.
fn ragged_dim(rng: &mut Rng, hi: usize) -> usize {
    let n = rng.range(1, hi);
    if rng.chance(0.25) {
        (n.div_ceil(NB) * NB).min(hi.div_ceil(NB) * NB)
    } else {
        n
    }
}

fn tensor(rng: &mut Rng, shape: Vec<usize>) -> HostTensor {
    let n = shape.iter().product();
    HostTensor::new(shape, rng.normal_vec_f32(n, 0.5))
}

// ---------------------------------------------------------------------------
// Packed matmul core
// ---------------------------------------------------------------------------

#[test]
fn blocked_matmul_matches_reference_bitwise() {
    check_default("blocked matmul ≡ scalar reference", |rng| {
        let rows = rng.range(1, 24);
        let k = ragged_dim(rng, 70);
        let cols = ragged_dim(rng, 70);
        let a = rng.normal_vec_f32(rows * k, 0.5);
        let b = rng.normal_vec_f32(k * cols, 0.5);
        let packed = PackedRhs::pack_slice(&b, k, cols, None);
        let got = packed.matmul(&a, rows);
        let want = reference::matmul(&a, rows, k, &b, cols);
        prop_assert!(
            bits_eq(&got, &want),
            "blocked [{rows}x{k}]@[{k}x{cols}] diverges from reference"
        );
        prop_assert!(bits_eq(&packed.dequantized(), &b), "f32 pack/unpack not lossless");
        Ok(())
    });
}

/// The packed matmul dispatches per-call to the AVX2 8-lane kernel
/// whenever the host CPU reports the feature (SSE2 4-lane otherwise;
/// portable scalar off x86_64 or without `--features simd`). Whatever
/// width this machine lands on, the bits must match the scalar
/// reference — panel-exact shapes stress the full 16-lane vector path,
/// ragged ones the zero-padded tail panels.
#[test]
fn simd_width_dispatch_is_bitwise_invisible() {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    eprintln!(
        "simd width under test: {}",
        if is_x86_feature_detected!("avx2") { "avx2 (8-lane)" } else { "sse2 (4-lane)" }
    );
    check_default("native-width matmul ≡ scalar reference", |rng| {
        let rows = rng.range(1, 9);
        // Half the draws are exact multiples of NB so every accumulate
        // runs the full-panel vector path; the rest leave ragged tails.
        let (k, cols) = if rng.chance(0.5) {
            (NB * rng.range(1, 5), NB * rng.range(1, 5))
        } else {
            (ragged_dim(rng, 4 * NB), ragged_dim(rng, 4 * NB))
        };
        let a = rng.normal_vec_f32(rows * k, 0.5);
        let b = rng.normal_vec_f32(k * cols, 0.5);
        let packed = PackedRhs::pack_slice(&b, k, cols, None);
        prop_assert!(
            bits_eq(&packed.matmul(&a, rows), &reference::matmul(&a, rows, k, &b, cols)),
            "native-width [{rows}x{k}]@[{k}x{cols}] diverges from scalar reference"
        );
        Ok(())
    });
}

#[test]
fn fused_quant_matmul_matches_reference_on_dequantized() {
    for kind in [QuantKind::Int8, QuantKind::Int4] {
        check_default(&format!("fused {} matmul ≡ reference on dequantized", kind.name()), |rng| {
            let rows = rng.range(1, 16);
            let k = ragged_dim(rng, 50);
            // Cross the QUANT_GROUP boundary and leave ragged tail groups.
            let cols = rng.range(1, 2 * QUANT_GROUP + NB + 3);
            let a = rng.normal_vec_f32(rows * k, 0.5);
            let b = rng.normal_vec_f32(k * cols, 0.5);
            let packed = PackedRhs::pack_slice(&b, k, cols, Some(kind));
            let got = packed.matmul(&a, rows);
            let deq = packed.dequantized();
            let want = reference::matmul(&a, rows, k, &deq, cols);
            prop_assert!(
                bits_eq(&got, &want),
                "fused {} [{rows}x{k}]@[{k}x{cols}] diverges from dequantized reference",
                kind.name()
            );
            Ok(())
        });
    }
}

// ---------------------------------------------------------------------------
// Head, gate, expert module
// ---------------------------------------------------------------------------

#[test]
fn blocked_head_and_topk_gate_match_reference() {
    check_default("blocked head + top-k gate ≡ reference", |rng| {
        let b = rng.range(1, 6);
        let h = ragged_dim(rng, 48);
        let v = ragged_dim(rng, 60);
        let e = rng.range(2, 9);
        // Hit both top-k edges (k = 1, k = E) often.
        let top_k = match rng.below(4) {
            0 => 1,
            1 => e,
            _ => rng.range(1, e),
        };
        let x = tensor(rng, vec![b, h]);
        let ln = tensor(rng, vec![h]);
        let unembed = tensor(rng, vec![h, v]);
        let router = tensor(rng, vec![h, e]);

        let got = kernels::head(&x, &HeadWeights::new(&ln, &unembed));
        let want = reference::head(&x, &ln, &unembed);
        prop_assert!(bits_eq(&got.data, &want.data), "head [{b}x{h}]→[{b}x{v}] diverges");

        let xn = kernels::rms_norm(&x, &ln);
        let got = kernels::topk_gate(&xn, &PackedRhs::pack(&router, None), top_k);
        let want = reference::topk_gate(&xn, &router, top_k);
        prop_assert!(bits_eq(&got.data, &want.data), "top-{top_k}/{e} gate diverges");
        Ok(())
    });
}

#[test]
fn blocked_expert_module_matches_reference() {
    check_default("sparse-gather expert module ≡ dense reference", |rng| {
        let t = rng.range(1, 8);
        let h = ragged_dim(rng, 40);
        let i = ragged_dim(rng, 40);
        let e = rng.range(2, 8);
        let top_k = match rng.below(4) {
            0 => 1,
            1 => e,
            _ => rng.range(1, e),
        };
        let x = tensor(rng, vec![t, h]);
        let ln = tensor(rng, vec![h]);
        let router = tensor(rng, vec![h, e]);
        let wg = tensor(rng, vec![e, h, i]);
        let wu = tensor(rng, vec![e, h, i]);
        let wd = tensor(rng, vec![e, i, h]);

        let shard = vec![ln.clone(), router.clone(), wg.clone(), wu.clone(), wd.clone()];
        let packed = ExpertWeights::from_shard(&shard, 1, None).unwrap();
        let got = kernels::expert_module(&x, &packed, top_k).unwrap();
        let want = reference::expert_module(&x, &shard, 1, top_k).unwrap();
        prop_assert!(
            bits_eq(&got.data, &want.data),
            "expert module t={t} h={h} i={i} top-{top_k}/{e} diverges"
        );

        // EP block variant: a contiguous half of the experts behind a
        // one-hot selector (how `shard_expert` hands EP shards over).
        let e_l = e / 2;
        if e_l > 0 {
            let b0 = rng.below(2) * e_l;
            let mut sel = vec![0f32; e_l * e];
            for j in 0..e_l {
                sel[j * e + b0 + j] = 1.0;
            }
            let block = |t3: &HostTensor, k: usize, cols: usize| {
                HostTensor::new(
                    vec![e_l, k, cols],
                    t3.data[b0 * k * cols..(b0 + e_l) * k * cols].to_vec(),
                )
            };
            let shard = vec![
                ln,
                router,
                HostTensor::new(vec![e_l, e], sel),
                block(&wg, h, i),
                block(&wu, h, i),
                block(&wd, i, h),
            ];
            let packed = ExpertWeights::from_shard(&shard, 2, None).unwrap();
            let got = kernels::expert_module(&x, &packed, top_k).unwrap();
            let want = reference::expert_module(&x, &shard, 2, top_k).unwrap();
            prop_assert!(
                bits_eq(&got.data, &want.data),
                "EP expert block [{b0}, {}) top-{top_k}/{e} diverges",
                b0 + e_l
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// Attention (GQA prefill, ranged chunks, decode, slot decode)
// ---------------------------------------------------------------------------

/// Random attention shard `[ln, wq, wk, wv, wo]` for a GQA geometry.
fn attn_shard(rng: &mut Rng, h: usize, q: usize, kv: usize, hd: usize) -> Vec<HostTensor> {
    vec![
        tensor(rng, vec![h]),
        tensor(rng, vec![h, q * hd]),
        tensor(rng, vec![h, kv * hd]),
        tensor(rng, vec![h, kv * hd]),
        tensor(rng, vec![q * hd, h]),
    ]
}

#[test]
fn blocked_attention_prefill_matches_reference() {
    check_default("blocked GQA prefill ≡ reference", |rng| {
        let b = rng.range(1, 3);
        let s = rng.range(1, 6);
        let h = ragged_dim(rng, 36);
        let kv = rng.range(1, 3);
        let q = kv * rng.range(1, 3);
        let hd = rng.range(1, 7);
        let x = tensor(rng, vec![b, s, h]);
        let shard = attn_shard(rng, h, q, kv, hd);
        let packed = AttnWeights::from_shard(&shard, None).unwrap();

        let (got, gk, gv) = kernels::attention_prefill(&x, &packed, q, kv, hd).unwrap();
        let (want, wk, wv) = reference::attention_prefill(&x, &shard, q, kv, hd).unwrap();
        prop_assert!(bits_eq(&got.data, &want.data), "prefill out b={b} s={s} q={q}/{kv} hd={hd}");
        prop_assert!(bits_eq(&gk.data, &wk.data), "prefill K diverges");
        prop_assert!(bits_eq(&gv.data, &wv.data), "prefill V diverges");
        Ok(())
    });
}

#[test]
fn blocked_ranged_prefill_matches_reference() {
    check_default("blocked ranged prefill chunk ≡ reference", |rng| {
        let h = ragged_dim(rng, 36);
        let kv = rng.range(1, 3);
        let q = kv * rng.range(1, 3);
        let hd = rng.range(1, 7);
        let c = rng.range(1, 5);
        let start = rng.range(0, 4);
        let slots = 2;
        let m = start + c + rng.range(0, 3);
        let row = rng.below(slots);
        let x = tensor(rng, vec![1, c, h]);
        let shard = attn_shard(rng, h, q, kv, hd);
        let packed = AttnWeights::from_shard(&shard, None).unwrap();

        // Both paths resume against the same already-written KV prefix.
        let kc0 = tensor(rng, vec![slots, m, kv * hd]);
        let vc0 = tensor(rng, vec![slots, m, kv * hd]);
        let (mut kc_a, mut vc_a) = (kc0.clone(), vc0.clone());
        let (mut kc_b, mut vc_b) = (kc0, vc0);
        let got = kernels::attention_prefill_ranged(
            &x, &mut kc_a, &mut vc_a, row, start, &packed, q, kv, hd,
        )
        .unwrap();
        let want = reference::attention_prefill_ranged(
            &x, &mut kc_b, &mut vc_b, row, start, &shard, q, kv, hd,
        )
        .unwrap();
        prop_assert!(bits_eq(&got.data, &want.data), "chunk out {start}..{} row {row}", start + c);
        prop_assert!(bits_eq(&kc_a.data, &kc_b.data), "chunk K cache diverges");
        prop_assert!(bits_eq(&vc_a.data, &vc_b.data), "chunk V cache diverges");
        Ok(())
    });
}

#[test]
fn blocked_decode_and_slot_decode_match_reference() {
    check_default("blocked decode / slot decode ≡ reference", |rng| {
        let b = rng.range(1, 4);
        let h = ragged_dim(rng, 36);
        let kv = rng.range(1, 3);
        let q = kv * rng.range(1, 3);
        let hd = rng.range(1, 7);
        let m = rng.range(1, 8);
        let x = tensor(rng, vec![b, 1, h]);
        let shard = attn_shard(rng, h, q, kv, hd);
        let packed = AttnWeights::from_shard(&shard, None).unwrap();
        let kc0 = tensor(rng, vec![b, m, kv * hd]);
        let vc0 = tensor(rng, vec![b, m, kv * hd]);

        // Uniform decode (every row at the same position).
        let pos = rng.below(m);
        let (mut kc_a, mut vc_a) = (kc0.clone(), vc0.clone());
        let (mut kc_b, mut vc_b) = (kc0.clone(), vc0.clone());
        let got =
            kernels::attention_decode(&x, &mut kc_a, &mut vc_a, pos, &packed, q, kv, hd).unwrap();
        let want =
            reference::attention_decode(&x, &mut kc_b, &mut vc_b, pos, &shard, q, kv, hd).unwrap();
        prop_assert!(bits_eq(&got.data, &want.data), "decode out pos={pos}/{m} diverges");
        prop_assert!(bits_eq(&kc_a.data, &kc_b.data), "decode K cache diverges");

        // Slot decode: ragged positions, some rows retired.
        let pos: Vec<usize> = (0..b).map(|_| rng.below(m)).collect();
        let active: Vec<bool> = (0..b).map(|_| rng.chance(0.7)).collect();
        let (mut kc_a, mut vc_a) = (kc0.clone(), vc0.clone());
        let (mut kc_b, mut vc_b) = (kc0, vc0);
        let got = kernels::attention_decode_slots(
            &x, &mut kc_a, &mut vc_a, &pos, &active, &packed, q, kv, hd,
        )
        .unwrap();
        let want = reference::attention_decode_slots(
            &x, &mut kc_b, &mut vc_b, &pos, &active, &shard, q, kv, hd,
        )
        .unwrap();
        prop_assert!(bits_eq(&got.data, &want.data), "slot decode out {pos:?}/{active:?}");
        prop_assert!(bits_eq(&kc_a.data, &kc_b.data), "slot decode K cache diverges");
        prop_assert!(bits_eq(&vc_a.data, &vc_b.data), "slot decode V cache diverges");
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// End-to-end quantized serving: exact-grid weights → identical tokens
// ---------------------------------------------------------------------------

/// Fill a weight tensor with values that sit exactly on `kind`'s
/// quantization grid, with both grid endpoints present in every
/// per-`(row, group)` quantization group. The group's affine params
/// then come out exact (int8: scale `1/256`, zero 0; int4: `1/16`,
/// zero 0 — all powers of two), so quantize→dequantize reproduces every
/// weight bit-for-bit and the quantized engine must emit the same
/// greedy tokens as f32.
fn grid_tensor(shape: &[usize], kind: QuantKind, salt: usize) -> HostTensor {
    let cols = *shape.last().unwrap();
    let rows: usize = shape.iter().product::<usize>() / cols;
    let (lo_n, hi_n, denom, stride) = match kind {
        QuantKind::Int8 => (-128i32, 127i32, 256.0f32, 37usize),
        QuantKind::Int4 => (-8, 7, 16.0, 5),
    };
    let span = (hi_n - lo_n + 1) as usize;
    let mut data = Vec::with_capacity(rows * cols);
    for r in 0..rows {
        let mut c = 0;
        while c < cols {
            let gs = (cols - c).min(QUANT_GROUP);
            for j in 0..gs {
                let n = if gs < 2 {
                    0
                } else if j == 0 {
                    lo_n
                } else if j == 1 {
                    hi_n
                } else {
                    lo_n + (((r * 31 + c + j + salt) * stride) % span) as i32
                };
                data.push(n as f32 / denom);
            }
            c += gs;
        }
    }
    HostTensor::new(shape.to_vec(), data)
}

/// Synthetic host-demo weights with every quantized matrix (attention
/// projections + expert FFN) replaced by exact-grid values.
fn grid_store(kind: QuantKind) -> WeightStore {
    let meta = TinyModelMeta::host_demo();
    let mut store = WeightStore::synthetic(&meta, 0xE16);
    for l in 0..meta.layers {
        for (salt, name) in ["wq", "wk", "wv", "wo", "wg", "wu", "wd"].iter().enumerate() {
            let full = format!("layer{l}.{name}");
            let shape = store.get(&full).unwrap().shape.clone();
            store.replace(&full, grid_tensor(&shape, kind, l * 7 + salt)).unwrap();
        }
    }
    store
}

fn quant_workload(meta: &TinyModelMeta) -> Vec<Request> {
    (0..meta.batch as u64)
        .map(|i| {
            let prompt: Vec<i32> =
                (0..12).map(|t| ((i as usize * 29 + t * 13 + 7) % meta.vocab) as i32).collect();
            Request::new(i, prompt, 6)
        })
        .collect()
}

fn assert_quant_tokens_identical(kind: QuantKind) {
    let meta = TinyModelMeta::host_demo();
    // tp(1): shard tensors are the full matrices, so slicing cannot
    // move quantization-group boundaries off the grid layout.
    let config = ServeConfig::tp(1);
    let mut exec = ModelExecutor::host(grid_store(kind));
    let f32_report = serve_on(&mut exec, &config, quant_workload(&meta)).unwrap();

    let mut qconfig = config;
    qconfig.quant = Some(kind);
    let mut exec = ModelExecutor::host(grid_store(kind));
    let q_report = serve_on(&mut exec, &qconfig, quant_workload(&meta)).unwrap();

    let by_id = |mut rs: Vec<hap::serving::server::Response>| {
        rs.sort_by_key(|r| r.id);
        rs
    };
    let (f32_rs, q_rs) = (by_id(f32_report.responses), by_id(q_report.responses));
    assert_eq!(f32_rs.len(), q_rs.len());
    for (a, b) in f32_rs.iter().zip(&q_rs) {
        assert!(!a.tokens.is_empty(), "request {} generated nothing", a.id);
        assert_eq!(
            a.tokens, b.tokens,
            "{} serving changed request {}'s greedy tokens",
            kind.name(),
            a.id
        );
    }
}

#[test]
fn int8_serving_emits_identical_greedy_tokens_on_grid_weights() {
    assert_quant_tokens_identical(QuantKind::Int8);
}

#[test]
fn int4_serving_emits_identical_greedy_tokens_on_grid_weights() {
    assert_quant_tokens_identical(QuantKind::Int4);
}

/// The premise of the serving test, checked directly: grid weights
/// survive quantize→dequantize bit-for-bit (including tensors whose
/// trailing group is ragged).
#[test]
fn grid_tensors_round_trip_exactly() {
    for kind in [QuantKind::Int8, QuantKind::Int4] {
        for shape in [vec![3, 96], vec![2, 5, 64], vec![4, 32], vec![7, 130]] {
            let t = grid_tensor(&shape, kind, 3);
            let cols = *shape.last().unwrap();
            let packed = PackedRhs::pack_slice(&t.data, t.data.len() / cols, cols, Some(kind));
            assert!(
                bits_eq(&packed.dequantized(), &t.data),
                "{} grid round-trip lost bits for shape {shape:?}",
                kind.name()
            );
        }
    }
}
