//! Tier-1 acceptance for the streaming `Engine` API (ISSUE 4):
//!
//! - per-request generated tokens are **bit-identical** between the
//!   streaming engine (continuous batching, per-slot KV) and the
//!   gang-scheduled compat wrapper, under a fixed plan and under the
//!   adaptive policy — every kernel is row-independent, so a sequence's
//!   tokens depend only on its own padded prompt and the weights;
//! - a forced mid-run plan switch (expert-only reshard) is invisible in
//!   outputs while moving real weights;
//! - slot join/leave keeps KV isolated: a sequence decodes the same
//!   tokens alone as it does while peers churn around it;
//! - a workload 4× the queue capacity completes (the old `serve_on`
//!   aborted with `bail!`);
//! - weight uploads stay flat across streaming iterations under a
//!   fixed plan.
//!
//! Everything runs artifact-free on the host grid engine.

use hap::model::{EngineMode, ModelExecutor, ShardPlan, WeightStore};
use hap::runtime::literal::argmax_rows;
use hap::runtime::TinyModelMeta;
use hap::serving::{
    serve_on, serve_with, Batcher, Engine, Request, RequestStatus, Scheduling, ServeConfig,
    ServeReport,
};
use hap::strategy::{AttnStrategy, ExpertStrategy};
use hap::util::rng::Rng;

fn meta() -> TinyModelMeta {
    TinyModelMeta::host_demo()
}

fn weights(seed: u64) -> WeightStore {
    WeightStore::synthetic(&meta(), seed)
}

/// Mixed-length workload: prompts and generation budgets vary, so gang
/// batches convoy while the streaming engine backfills slots.
fn mixed_workload(m: &TinyModelMeta, n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n as u64)
        .map(|id| {
            let len = rng.range(m.prefill_len / 2, m.prefill_len);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(m.vocab) as i32).collect();
            let gen = rng.range(2, 8);
            Request::new(id, prompt, gen)
        })
        .collect()
}

fn sorted_tokens(report: &ServeReport) -> Vec<(u64, Vec<i32>)> {
    let mut t: Vec<(u64, Vec<i32>)> =
        report.responses.iter().map(|r| (r.id, r.tokens.clone())).collect();
    t.sort();
    t
}

#[test]
fn streaming_tokens_bit_identical_to_gang_fixed_plan() {
    let m = meta();
    for config in [ServeConfig::tp(4), ServeConfig::hap_transition(4)] {
        let mut exec = ModelExecutor::host(weights(42));
        let gang = serve_on(&mut exec, &config, mixed_workload(&m, 10, 2)).unwrap();

        let mut engine = Engine::builder(config.clone()).build_host(weights(42));
        for req in mixed_workload(&m, 10, 2) {
            engine.submit(req).unwrap();
        }
        let streaming = engine.shutdown().unwrap();

        assert_eq!(gang.metrics.requests_completed, 10);
        assert_eq!(streaming.metrics.requests_completed, 10);
        assert_eq!(
            sorted_tokens(&gang),
            sorted_tokens(&streaming),
            "streaming diverged from gang under {}",
            config.label()
        );
        // Continuous batching must not waste decode work on finished
        // slots: its occupancy is at least the convoy's.
        assert!(
            streaming.metrics.mean_occupancy() >= gang.metrics.mean_occupancy() - 1e-9,
            "streaming occupancy {} below gang {}",
            streaming.metrics.mean_occupancy(),
            gang.metrics.mean_occupancy()
        );
    }
}

#[test]
fn streaming_matches_gang_under_adaptive_policy() {
    // Adaptive plan selection runs per batch (gang) vs per admission
    // boundary (streaming); the plans each controller lands on may even
    // differ — generated tokens must not. NOTE: across *different*
    // layouts equality is token-level, not logit-level (f32 partial
    // sums fold in layout order; logits agree to ~1e-3) — the same
    // invariant grid_engine.rs pins for this model/weight seed. Short
    // generations keep the exposed argmax positions few.
    let m = meta();
    // Two traffic phases: short-gen burst, then longer requests.
    let mut workload = mixed_workload(&m, 6, 7);
    for (i, req) in workload.iter_mut().enumerate() {
        req.max_new_tokens = if i < 3 { 2 } else { 6 };
    }

    let config = ServeConfig::adaptive(4);
    let mut exec = ModelExecutor::host(weights(42));
    let gang = serve_on(&mut exec, &config, workload.clone()).unwrap();

    let mut engine = Engine::builder(config).build_host(weights(42));
    for req in workload {
        engine.submit(req).unwrap();
    }
    let streaming = engine.shutdown().unwrap();

    assert_eq!(
        sorted_tokens(&gang),
        sorted_tokens(&streaming),
        "adaptive streaming diverged from adaptive gang"
    );
}

#[test]
fn forced_mid_run_switch_reshards_without_changing_tokens() {
    let m = meta();
    let mut exec = ModelExecutor::host(weights(42));
    let reference = serve_on(&mut exec, &ServeConfig::tp(4), mixed_workload(&m, 8, 5)).unwrap();

    let mut engine = Engine::builder(ServeConfig::tp(4)).build_host(weights(42));
    for req in mixed_workload(&m, 8, 5) {
        engine.submit(req).unwrap();
    }
    // A few iterations under TP4 with sequences in flight...
    for _ in 0..3 {
        let out = engine.step().unwrap();
        assert!(out.running > 0);
    }
    // ...then force the hybrid expert layout. Attention is unchanged,
    // so the reshard applies mid-decode without draining.
    let hybrid = ShardPlan::new(AttnStrategy::new(4, 1), ExpertStrategy::new(2, 2));
    engine.force_plans(hybrid, hybrid).unwrap();
    let report = engine.shutdown().unwrap();

    assert!(report.metrics.reshards >= 1, "forced switch moved no weights");
    assert_eq!(
        sorted_tokens(&reference),
        sorted_tokens(&report),
        "mid-run expert switch changed generated tokens"
    );
}

#[test]
fn slot_join_leave_keeps_kv_isolated() {
    // Property: a sequence's decode trajectory is bit-identical whether
    // it runs alone in the session or while peers join and leave its
    // batch. Drives the executor's slot API directly.
    let m = meta();
    let plan = ShardPlan::tp(4);
    let batcher = Batcher::new(m.batch, m.prefill_len, m.max_len - m.prefill_len);
    let target = Request::new(0, (0..12).map(|i| (i * 5 + 3) % m.vocab as i32).collect(), 6);
    let peer_a = Request::new(1, (0..9).map(|i| (i * 11 + 1) % m.vocab as i32).collect(), 6);
    let peer_b = Request::new(2, (0..14).map(|i| (i * 7 + 2) % m.vocab as i32).collect(), 6);
    let (target_row, _) = batcher.pack_one(&target);
    let (peer_a_row, _) = batcher.pack_one(&peer_a);
    let (peer_b_row, _) = batcher.pack_one(&peer_b);
    let steps = 5usize;

    // Reference: the target alone.
    let mut alone: Vec<i32> = Vec::new();
    {
        let mut exec = ModelExecutor::host_with_mode(weights(42), EngineMode::Sequential);
        exec.begin_session(&plan, &plan).unwrap();
        let s = exec.claim_slot().unwrap();
        let logits = exec.prefill_slot(s, &target_row, &plan).unwrap();
        let mut last = vec![0i32; m.batch];
        last[s] = argmax_rows(&logits)[0] as i32;
        alone.push(last[s]);
        for _ in 0..steps {
            let logits = exec.decode_slots(&last, &plan).unwrap();
            last[s] = argmax_rows(&logits)[s] as i32;
            alone.push(last[s]);
        }
    }

    // Churn: peer A occupies slot 0 first, the target lands in slot 1;
    // A leaves mid-run and B takes A's old slot with a fresh prompt.
    let mut churn: Vec<i32> = Vec::new();
    {
        let mut exec = ModelExecutor::host_with_mode(weights(42), EngineMode::Sequential);
        exec.begin_session(&plan, &plan).unwrap();
        let sa = exec.claim_slot().unwrap();
        assert_eq!(sa, 0);
        let la = exec.prefill_slot(sa, &peer_a_row, &plan).unwrap();
        let st = exec.claim_slot().unwrap();
        assert_eq!(st, 1, "target joins the second slot");
        let lt = exec.prefill_slot(st, &target_row, &plan).unwrap();
        let mut last = vec![0i32; m.batch];
        last[sa] = argmax_rows(&la)[0] as i32;
        last[st] = argmax_rows(&lt)[0] as i32;
        churn.push(last[st]);
        for step in 0..steps {
            if step == 2 {
                // Peer A retires mid-decode; its slot is recycled for
                // peer B, whose chunked prefill runs between decode
                // iterations.
                exec.release_slot(sa).unwrap();
                let sb = exec.claim_slot().unwrap();
                assert_eq!(sb, sa, "freed slot must be reused");
                let lb = exec.prefill_slot(sb, &peer_b_row, &plan).unwrap();
                last[sb] = argmax_rows(&lb)[0] as i32;
            }
            let logits = exec.decode_slots(&last, &plan).unwrap();
            let next = argmax_rows(&logits);
            for slot in 0..m.batch {
                if exec.slot_liveness()[slot] {
                    last[slot] = next[slot] as i32;
                }
            }
            churn.push(last[st]);
        }
    }

    assert_eq!(alone, churn, "peer churn leaked into the target's KV");
}

#[test]
fn chunked_prefill_bit_identical_under_fixed_and_adaptive_plans() {
    // A long prompt split across >= 2 admission iterations must yield
    // per-request tokens bit-identical to the gang scheduler AND to
    // unchunked streaming — under the fixed TP plan, the HAP
    // prefill->decode transition plan, and the adaptive policy.
    let m = meta();
    for base in [
        ServeConfig::tp(4),
        ServeConfig::hap_transition(4),
        ServeConfig::adaptive(4),
    ] {
        let mut base = base;
        let mut workload = mixed_workload(&m, 10, 23);
        if let Some(a) = &mut base.adaptive {
            // The consult/measured-feedback path still runs, but the
            // switch economics are pinned shut: measured wall-clock
            // noise now feeds the controller, and this test is about
            // chunking bit-identity, not plan-choice agreement — all
            // three runs must deterministically stay on the adopted
            // plan. Short generations additionally keep the exposed
            // argmax positions few (same caveat as the adaptive-policy
            // test: across different layouts equality is token-level).
            a.controller.breakeven_factor = 1e12;
            for (i, req) in workload.iter_mut().enumerate() {
                req.max_new_tokens = if i < 5 { 2 } else { 6 };
            }
        }
        let mut exec = ModelExecutor::host(weights(42));
        let gang = serve_on(&mut exec, &base, workload.clone()).unwrap();

        let mut engine = Engine::builder(base.clone()).build_host(weights(42));
        for req in workload.clone() {
            engine.submit(req).unwrap();
        }
        let unchunked = engine.shutdown().unwrap();
        assert_eq!(sorted_tokens(&gang), sorted_tokens(&unchunked), "{}", base.label());

        // 5-token chunks on 16-token padded rows: ceil(16/5) = 4
        // iterations per joiner.
        let mut config = base.clone();
        config.prefill_chunk = 5;
        let mut engine = Engine::builder(config).build_host(weights(42));
        for req in workload.clone() {
            engine.submit(req).unwrap();
        }
        let chunked = engine.shutdown().unwrap();
        assert_eq!(
            sorted_tokens(&gang),
            sorted_tokens(&chunked),
            "chunked prefill diverged under {}",
            base.label()
        );
        assert_eq!(
            chunked.metrics.prefill_chunks,
            4 * chunked.metrics.batches_prefilled,
            "each 16-token prompt must take 4 five-token chunks"
        );
        assert_eq!(
            unchunked.metrics.prefill_chunks, unchunked.metrics.batches_prefilled,
            "unchunked prefill is one chunk per joiner"
        );
    }
}

#[test]
fn chunked_prefill_survives_forced_mid_run_switch() {
    let m = meta();
    let mut exec = ModelExecutor::host(weights(42));
    let reference = serve_on(&mut exec, &ServeConfig::tp(4), mixed_workload(&m, 8, 5)).unwrap();

    let mut config = ServeConfig::tp(4);
    config.prefill_chunk = 6;
    let mut engine = Engine::builder(config).build_host(weights(42));
    for req in mixed_workload(&m, 8, 5) {
        engine.submit(req).unwrap();
    }
    for _ in 0..3 {
        let out = engine.step().unwrap();
        assert!(out.running > 0);
    }
    // Expert-only switch mid-run, with slots potentially mid-chunk.
    let hybrid = ShardPlan::new(AttnStrategy::new(4, 1), ExpertStrategy::new(2, 2));
    engine.force_plans(hybrid, hybrid).unwrap();
    let report = engine.shutdown().unwrap();
    assert!(report.metrics.reshards >= 1, "forced switch moved no weights");
    assert_eq!(
        sorted_tokens(&reference),
        sorted_tokens(&report),
        "mid-run expert switch under chunked prefill changed tokens"
    );
}

#[test]
fn chunked_prefill_interleaves_peer_decode_and_defers_first_token() {
    // A long-prompt joiner admitted while a peer is decoding: with a
    // 4-token chunk its 16-token padded prompt takes 4 admission
    // iterations, each of which ALSO runs the peer's decode step —
    // the head-of-line block is gone — and the joiner's first token
    // (hence its TTFT) lands only with the final chunk.
    let m = meta();
    let mut config = ServeConfig::tp(4);
    config.prefill_chunk = 4;
    let mut engine =
        Engine::builder(config).build_host_with_mode(weights(13), EngineMode::Sequential);
    engine.submit(Request::new(0, vec![1, 2, 3], 30)).unwrap();
    // The peer's own prefill takes 4 chunk iterations, then it decodes.
    for _ in 0..4 {
        engine.step().unwrap();
    }
    match engine.poll(0) {
        RequestStatus::Running { tokens } => assert!(!tokens.is_empty(), "peer not decoding"),
        other => panic!("expected running peer, got {other:?}"),
    }

    engine.submit(Request::new(1, vec![4, 5, 6, 7, 8], 3)).unwrap();
    for i in 0..4 {
        let out = engine.step().unwrap();
        if i == 0 {
            assert_eq!(out.admitted, 1, "joiner admitted on its first chunk");
        }
        if i < 3 {
            // Mid-prefill: only the peer decodes, and the joiner has
            // produced nothing yet.
            assert_eq!(out.decoded, 1, "peer decode not interleaved at chunk {i}");
            match engine.poll(1) {
                RequestStatus::Running { tokens } => {
                    assert!(tokens.is_empty(), "first token before the final chunk")
                }
                other => panic!("expected prefilling joiner, got {other:?}"),
            }
        } else {
            // Final chunk: first token lands AND the joiner takes its
            // first decode step in the same iteration (exactly like an
            // unchunked admission).
            assert_eq!(out.decoded, 2, "joiner must start decoding with its peer");
            match engine.poll(1) {
                RequestStatus::Running { tokens } => assert_eq!(tokens.len(), 2),
                other => panic!("expected decoding joiner, got {other:?}"),
            }
        }
    }
    engine.run_to_completion().unwrap();
    let report = engine.shutdown().unwrap();
    let joiner = report.responses.iter().find(|r| r.id == 1).unwrap();
    // TTFT/TPOT accounting with the first token on the final chunk:
    // the TTFT spans all four chunk iterations, the decode span only
    // the two decode steps after it.
    assert_eq!(joiner.tokens.len(), 3);
    assert!(joiner.ttft > 0.0, "TTFT never measured");
    assert!(
        joiner.ttft <= joiner.latency,
        "TTFT {} exceeds total latency {}",
        joiner.ttft,
        joiner.latency
    );
    assert_eq!(report.metrics.prefill_chunks, 8, "two 4-chunk prefills expected");
    // Both requests decoded past their first token, so both contribute
    // a TPOT sample.
    assert!(report.metrics.tpot_p(50.0) > 0.0, "no TPOT samples recorded");
}

#[test]
fn attention_switch_on_empty_running_set_applies_without_dead_iteration() {
    // An attention-layout switch decided when nothing is running used
    // to take the pending/backlog detour and burn a dead iteration
    // before admitting; it must apply on the spot instead.
    let m = meta();
    let mut engine = Engine::builder(ServeConfig::tp(4)).build_host(weights(11));
    for req in mixed_workload(&m, 2, 40) {
        engine.submit(req).unwrap();
    }
    engine.run_to_completion().unwrap(); // running set drains to empty
    let reshards_before = engine.executor().stats().reshards;
    let dp = ShardPlan::new(AttnStrategy::new(2, 2), ExpertStrategy::new(2, 2));
    engine.force_plans(dp, dp).unwrap();
    assert!(
        engine.executor().stats().reshards > reshards_before,
        "empty-set attention switch was deferred instead of applied"
    );
    // The very next step admits under the new layout — no dead
    // iteration, no backlog detour.
    engine.submit(Request::new(90, vec![1, 2, 3], 2)).unwrap();
    let out = engine.step().unwrap();
    assert_eq!(out.admitted, 1, "dead iteration before admission");
    engine.run_to_completion().unwrap();
    let report = engine.shutdown().unwrap();
    assert_eq!(report.metrics.requests_completed, 3);
}

#[test]
fn streaming_admission_feeds_measured_latency_to_controller() {
    // The streaming engine must close the measured-latency loop with
    // NO gang batch involved: after a second admission boundary (the
    // first consult has no completed dwell window yet), the
    // controller's mispredict EWMA for the active plan must hold an
    // observation.
    let m = meta();
    let mut engine = Engine::builder(ServeConfig::adaptive(4)).build_host(weights(42));
    // Two admission waves: 4 requests fill the batch, 4 more join as
    // slots free up, so the adapt loop is consulted at least twice
    // with executed iterations in between.
    for req in mixed_workload(&m, 8, 31) {
        engine.submit(req).unwrap();
    }
    engine.run_to_completion().unwrap();
    let control = engine.adapt().expect("adaptive engine");
    // An entry exists only once observe_measured folded a real
    // observation — the loop is closed. (The value check is scoped to
    // the final active plan IF it is the one measured: the controller
    // may in principle adopt a different plan at the very last
    // boundary, which then has no window of its own yet.)
    assert!(
        control.controller.mispredict_observations() >= 1,
        "streaming run fed no measured latency into the controller"
    );
    let active = control.controller.active().expect("plan adopted");
    if let Some(ewma) = control.controller.mispredict_ewma(&active.signature()) {
        assert!(
            (ewma - 1.0).abs() > 1e-12,
            "mispredict EWMA never moved off its prior"
        );
    }
    let report = engine.shutdown().unwrap();
    assert_eq!(report.metrics.requests_completed, 8);
}

#[test]
fn workload_4x_queue_capacity_completes() {
    // Regression for the old hard `bail!` on queue overflow: admission
    // now backpressures by draining.
    let m = meta();
    let n = 16usize;
    let mut config = ServeConfig::tp(4);
    config.queue_capacity = 4; // n == 4x capacity

    let mut exec = ModelExecutor::host(weights(3));
    let gang = serve_on(&mut exec, &config, mixed_workload(&m, n, 1)).unwrap();
    assert_eq!(gang.metrics.requests_completed, n);
    assert_eq!(gang.responses.len(), n);

    let mut engine = Engine::builder(config).build_host(weights(3));
    for req in mixed_workload(&m, n, 1) {
        engine.submit(req).unwrap();
    }
    let streaming = engine.shutdown().unwrap();
    assert_eq!(streaming.metrics.requests_completed, n);
    assert_eq!(sorted_tokens(&gang), sorted_tokens(&streaming));
}

#[test]
fn streaming_uploads_flat_across_iterations_under_fixed_plan() {
    let m = meta();
    let config = ServeConfig::tp(4);
    let mut exec = ModelExecutor::host(weights(7));
    let r1 = serve_with(
        &mut exec,
        &config,
        Scheduling::Streaming,
        mixed_workload(&m, 2, 9),
    )
    .unwrap();
    assert!(r1.metrics.weight_uploads > 0, "cold start uploads shards");
    assert_eq!(r1.metrics.reshards, 0);

    // A second run on the same executor — and every iteration inside
    // it — rides the resident shards: zero new uploads.
    let r2 = serve_with(
        &mut exec,
        &config,
        Scheduling::Streaming,
        mixed_workload(&m, 8, 10),
    )
    .unwrap();
    assert_eq!(r2.metrics.weight_uploads, 0, "fixed plan re-uploaded weights");
    assert_eq!(r2.metrics.reshards, 0);
}

#[test]
fn poll_reports_lifecycle() {
    let m = meta();
    let mut engine = Engine::builder(ServeConfig::tp(4)).build_host(weights(11));
    // Fill every slot plus one queued straggler.
    let reqs = mixed_workload(&m, m.batch + 1, 4);
    let straggler = reqs[m.batch].id;
    for req in reqs {
        engine.submit(req).unwrap();
    }
    engine.step().unwrap();
    assert!(
        matches!(engine.poll(straggler), RequestStatus::Queued),
        "fifth request should wait for a freed slot"
    );
    assert!(engine.drain().is_empty(), "nothing finished after one iteration");
    engine.run_to_completion().unwrap();
    let responses = engine.drain();
    assert_eq!(responses.len(), m.batch + 1);
    assert!(matches!(engine.poll(straggler), RequestStatus::Finished(_)));
}
