//! Runtime-free grid-engine acceptance tests (ISSUE 3): the hybrid
//! EP×TP device grid executes natively on host math, bit-identical
//! between parallel (scoped-thread) and sequential execution, and
//! numerically equivalent to the pure-TP and pure-EP references; every
//! strategy the search space emits lowers to a well-formed grid; and
//! the serving loop holds one executor whose weight uploads are
//! amortized across batches, growing only on a plan switch.
//!
//! Everything here runs on `HostTensor` math over seeded synthetic
//! weights — no PJRT artifacts required (CI runs this suite as the
//! grid smoke job).

use hap::config::{MoEModelConfig, NodeConfig, Scenario};
use hap::model::{DeviceGrid, EngineMode, ModelExecutor, ShardPlan, WeightStore};
use hap::runtime::literal::{argmax_rows, HostTensor};
use hap::runtime::TinyModelMeta;
use hap::serving::{serve_on, Request, ServeConfig};
use hap::strategy::{AttnStrategy, ExpertStrategy, SearchSpace};

fn meta() -> TinyModelMeta {
    TinyModelMeta::host_demo()
}

fn weights(seed: u64) -> WeightStore {
    WeightStore::synthetic(&meta(), seed)
}

fn test_tokens(m: &TinyModelMeta) -> Vec<i32> {
    (0..m.batch * m.prefill_len)
        .map(|i| ((i * 37 + 11) % m.vocab) as i32)
        .collect()
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// Prefill + a few greedy decode steps under one plan; returns the
/// prefill logits and the generated token matrix.
fn run_plan(mode: EngineMode, plan: &ShardPlan, steps: usize) -> (HostTensor, Vec<Vec<usize>>) {
    let m = meta();
    let tokens = test_tokens(&m);
    let mut exec = ModelExecutor::host_with_mode(weights(42), mode);
    let logits = exec.prefill(&tokens, plan).unwrap();
    let mut out = vec![argmax_rows(&logits)];
    let mut last: Vec<i32> = out[0].iter().map(|&t| t as i32).collect();
    for _ in 0..steps {
        let logits = exec.decode_step(&last, plan).unwrap();
        let next = argmax_rows(&logits);
        last = next.iter().map(|&t| t as i32).collect();
        out.push(next);
    }
    (logits, out)
}

#[test]
fn hybrid_ep_tp_executes_natively_and_matches_references() {
    // The acceptance grid: ExpertStrategy { ep: 2, tp: 2 } on 4 devices.
    let hybrid = ShardPlan::new(AttnStrategy::new(4, 1), ExpertStrategy::new(2, 2));

    // Bit-equivalence: parallel per-device threads vs the sequential
    // reference path (combines run in fixed group order either way).
    let (par, par_toks) = run_plan(EngineMode::Parallel, &hybrid, 4);
    let (seq, seq_toks) = run_plan(EngineMode::Sequential, &hybrid, 4);
    assert_eq!(par.shape, seq.shape);
    let bits = |t: &HostTensor| t.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&par), bits(&seq), "parallel execution is not bit-identical");
    assert_eq!(par_toks, seq_toks);

    // Numerical equivalence to the pure references: the hybrid is an
    // exact re-partitioning, so only f32 summation order differs.
    let (tp4, tp4_toks) = run_plan(EngineMode::Sequential, &ShardPlan::tp(4), 4);
    let ep4 = ShardPlan::new(AttnStrategy::new(4, 1), ExpertStrategy::new(1, 4));
    let (ep4_logits, ep4_toks) = run_plan(EngineMode::Sequential, &ep4, 4);
    let d_tp = max_abs_diff(&par.data, &tp4.data);
    let d_ep = max_abs_diff(&par.data, &ep4_logits.data);
    assert!(d_tp < 1e-3, "hybrid vs pure-TP reference: max|Δ|={d_tp}");
    assert!(d_ep < 1e-3, "hybrid vs pure-EP reference: max|Δ|={d_ep}");
    assert_eq!(par_toks, tp4_toks, "hybrid grid changed greedy tokens vs TP");
    assert_eq!(par_toks, ep4_toks, "hybrid grid changed greedy tokens vs EP");
}

#[test]
fn dp_attention_and_stage_transition_match_tp_reference() {
    // DP2×TP2 attention (batch-split grid) with a prefill→decode expert
    // transition: tokens must match the static TP4 reference.
    let (_, base) = run_plan(EngineMode::Sequential, &ShardPlan::tp(4), 5);

    let m = meta();
    let tokens = test_tokens(&m);
    let mut exec = ModelExecutor::host_with_mode(weights(42), EngineMode::Parallel);
    let prefill = ShardPlan::new(AttnStrategy::new(2, 2), ExpertStrategy::new(2, 2));
    let decode = ShardPlan::new(AttnStrategy::new(2, 2), ExpertStrategy::new(4, 1));
    exec.begin_batch(&prefill, &decode).unwrap();
    let logits = exec.prefill(&tokens, &prefill).unwrap();
    let mut out = vec![argmax_rows(&logits)];
    let mut last: Vec<i32> = out[0].iter().map(|&t| t as i32).collect();
    for _ in 0..5 {
        let logits = exec.decode_step(&last, &decode).unwrap();
        let next = argmax_rows(&logits);
        last = next.iter().map(|&t| t as i32).collect();
        out.push(next);
    }
    assert_eq!(out, base, "DP×TP grid with stage transition changed tokens");
}

#[test]
fn every_search_space_strategy_lowers_to_a_valid_grid() {
    // Property: for every (model, node) the planner serves, every
    // (attn, expert) pair the search space emits lowers to a grid
    // whose roles partition the devices and whose groups are disjoint
    // and complete.
    let mut checked = 0usize;
    let nodes = [NodeConfig::a6000x(4), NodeConfig::a100x(8), NodeConfig::cpu_sim(4)];
    let mut models = MoEModelConfig::paper_models();
    models.push(MoEModelConfig::tiny_moe());
    for model in &models {
        for node in &nodes {
            let sc = Scenario::short_constrained();
            let space = SearchSpace::enumerate(model, node, &sc);
            for a in &space.attn {
                for e in &space.expert {
                    let plan = ShardPlan::new(*a, *e);
                    let grid = DeviceGrid::lower(&plan)
                        .unwrap_or_else(|err| panic!("{} failed to lower: {err}", plan.label()));
                    grid.check_dims(
                        model.q_heads,
                        model.kv_heads,
                        model.num_experts,
                        model.moe_inter_size,
                        sc.batch,
                    )
                    .unwrap_or_else(|err| panic!("{} not executable: {err}", plan.label()));
                    assert_grid_well_formed(&grid);
                    checked += 1;
                }
            }
        }
    }
    assert!(checked > 20, "search spaces unexpectedly small ({checked} grids)");
}

/// Roles partition devices; each group family partitions the device
/// set; combine groups hold exactly one leader per reduce group.
fn assert_grid_well_formed(grid: &DeviceGrid) {
    let n = grid.devices;
    let plan = &grid.plan;
    assert_eq!(grid.roles.len(), n);
    for (d, r) in grid.roles.iter().enumerate() {
        assert_eq!(r.device, d);
        assert_eq!(r.dp_rank * plan.attn.tp + r.tp_rank, d);
        assert_eq!(r.ep_rank * plan.expert.tp + r.etp_rank, d);
    }
    let partitions = |groups: &[hap::model::CollectiveGroup]| {
        let mut seen = vec![false; n];
        for g in groups {
            for &m in &g.members {
                assert!(m < n, "member {m} outside grid");
                assert!(!seen[m], "device {m} in two groups");
                seen[m] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "groups do not cover all devices");
    };
    partitions(&grid.attn_reduce);
    partitions(&grid.expert_reduce);
    assert_eq!(grid.batch_split.members.len(), grid.attn_reduce.len());
    for (g, leader) in grid.attn_reduce.iter().zip(&grid.batch_split.members) {
        assert!(g.members.contains(leader), "batch-split leader outside its group");
    }
    assert_eq!(grid.expert_combine.members.len(), grid.expert_reduce.len());
    for (g, leader) in grid.expert_reduce.iter().zip(&grid.expert_combine.members) {
        assert!(g.members.contains(leader), "combine leader outside its block");
    }
}

#[test]
fn weight_uploads_flat_under_fixed_plan_and_grow_only_on_switch() {
    let m = meta();
    let tokens = test_tokens(&m);
    let mut exec = ModelExecutor::host(weights(7));
    let a = ShardPlan::tp(4);
    let b = ShardPlan::new(AttnStrategy::new(4, 1), ExpertStrategy::new(2, 2));

    // Batch 1 under plan A: 4 devices × 2 layers × 2 families.
    exec.begin_batch(&a, &a).unwrap();
    exec.prefill(&tokens, &a).unwrap();
    exec.decode_step(&vec![1; m.batch], &a).unwrap();
    let s1 = exec.stats();
    assert_eq!(s1.materializations, 4 * m.layers * 2);
    assert_eq!(s1.reshards, 0);

    // Batch 2, same plan: uploads stay flat.
    exec.begin_batch(&a, &a).unwrap();
    exec.prefill(&tokens, &a).unwrap();
    let s2 = exec.stats();
    assert_eq!(s2.materializations, s1.materializations, "fixed plan re-uploaded weights");
    assert_eq!(s2.reshards, 0);

    // Batch 3 switches the expert layout: the old family is evicted,
    // the new one materialized — uploads strictly increase.
    exec.begin_batch(&b, &b).unwrap();
    exec.prefill(&tokens, &b).unwrap();
    let s3 = exec.stats();
    assert!(s3.materializations > s2.materializations);
    assert_eq!(s3.materializations, s2.materializations + 4 * m.layers);
    assert_eq!(s3.evictions, 4 * m.layers);
    assert_eq!(s3.reshards, 1);
    assert!(s3.reshard_seconds >= 0.0);

    // Batch 4, same plan again: flat.
    exec.begin_batch(&b, &b).unwrap();
    exec.prefill(&tokens, &b).unwrap();
    assert_eq!(exec.stats().materializations, s3.materializations);
}

fn workload(m: &TinyModelMeta, n: usize, gen: usize, seed: u64) -> Vec<Request> {
    let mut rng = hap::util::rng::Rng::new(seed);
    (0..n as u64)
        .map(|id| {
            let len = rng.range(m.prefill_len / 2, m.prefill_len);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(m.vocab) as i32).collect();
            Request::new(id, prompt, gen)
        })
        .collect()
}

#[test]
fn serve_on_amortizes_uploads_across_batches() {
    let m = meta();
    // Three batches through one long-lived executor.
    let mut exec = ModelExecutor::host(weights(3));
    let config = ServeConfig::tp(4);
    let report = serve_on(&mut exec, &config, workload(&m, 3 * m.batch, 3, 1)).unwrap();
    assert_eq!(report.metrics.batches_prefilled, 3);
    assert_eq!(report.metrics.requests_completed, 3 * m.batch);
    assert_eq!(report.metrics.reshards, 0);

    // One batch through a fresh executor: the upload count must match —
    // batches 2 and 3 rode on the warm shard cache.
    let mut exec1 = ModelExecutor::host(weights(3));
    let r1 = serve_on(&mut exec1, &config, workload(&m, m.batch, 3, 1)).unwrap();
    assert_eq!(r1.metrics.batches_prefilled, 1);
    assert_eq!(
        report.metrics.weight_uploads, r1.metrics.weight_uploads,
        "weight uploads not amortized across batches"
    );
}

#[test]
fn host_serving_tokens_invariant_across_plans() {
    // End-to-end serving equivalence on the host grid engine: static
    // TP, the HAP phase transition, and a hybrid EP×TP + DP×TP config
    // must generate identical tokens for the same workload.
    let m = meta();
    let hybrid = ServeConfig {
        attn: AttnStrategy::new(2, 2),
        expert_prefill: ExpertStrategy::new(2, 2),
        expert_decode: ExpertStrategy::new(4, 1),
        policy: hap::serving::RouterPolicy::Fcfs,
        queue_capacity: 1024,
        prefill_chunk: 0,
        pipeline_chunks: 1,
        prefill_budget_ms: 0.0,
        quant: None,
        kv: hap::model::KvLayout::Padded,
        adaptive: None,
    };
    let mut reference: Option<Vec<(u64, Vec<i32>)>> = None;
    for config in [ServeConfig::tp(4), ServeConfig::hap_transition(4), hybrid] {
        let mut exec = ModelExecutor::host(weights(11));
        let report = serve_on(&mut exec, &config, workload(&m, 6, 4, 2)).unwrap();
        let mut toks: Vec<(u64, Vec<i32>)> =
            report.responses.iter().map(|r| (r.id, r.tokens.clone())).collect();
        toks.sort();
        match &reference {
            None => reference = Some(toks),
            Some(base) => assert_eq!(
                base, &toks,
                "plan {} changed generated tokens",
                config.label()
            ),
        }
    }
}
