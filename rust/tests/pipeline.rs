//! Micro-chunk pipeline acceptance sweep (ISSUE 10).
//!
//! The pipelined iteration loop promises **bit-identical per-request
//! tokens** at any micro-chunk width `K`: chunk outputs are exact row
//! ranges concatenated in chunk order, never approximations, so the
//! module-sequential engine (`EngineMode::Sequential`, `K = 1`) stays
//! the oracle for every plan shape, KV layout, and fault schedule. The
//! sweeps here cross:
//!
//! - `K ∈ {1, 2, 3, 5, 8}` against the unchunked sequential oracle;
//! - plan shapes `tp`, `hap-hybrid` (EP prefill → TP decode), and
//!   `adaptive` (whatever plans the controller picks mid-run, tokens
//!   must not move);
//! - `padded` and `paged` KV layouts;
//! - crash / transient fault traces — compared at the **same `K` on
//!   both sides** so the iteration-clock fault schedules align;
//! - budget-driven chunk sizing (`prefill_budget_ms > 0`), which may
//!   pick any chunk sizes it likes but must not change a single token.
//!
//! Plus a ModuleTimes check: the pipelined path still attributes
//! attention / expert / collective time to the right buckets.

use hap::model::{EngineMode, FaultPlan, KvLayout, ModelExecutor, ShardPlan, WeightStore};
use hap::runtime::TinyModelMeta;
use hap::serving::{Engine, Request, ServeConfig};
use hap::strategy::{AttnStrategy, ExpertStrategy};
use hap::util::rng::Rng;

fn meta() -> TinyModelMeta {
    TinyModelMeta::host_demo()
}

/// Ragged prompt lengths (some duplicated, so the scheduler's
/// same-length chunk batching has real groups to merge) with short
/// generation budgets.
fn workload(m: &TinyModelMeta, n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n as u64)
        .map(|id| {
            let len = if id % 3 == 0 {
                m.prefill_len
            } else {
                rng.range(m.prefill_len / 2, m.prefill_len)
            };
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(m.vocab) as i32).collect();
            Request::new(id, prompt, rng.range(2, 7))
        })
        .collect()
}

/// Run `config` to completion on a fresh synthetic-weight engine and
/// return each request's tokens, sorted by id.
fn run_tokens(
    config: ServeConfig,
    mode: EngineMode,
    fault: Option<&str>,
    n: usize,
) -> Vec<(u64, Vec<i32>)> {
    let m = meta();
    let mut builder = Engine::builder(config);
    if let Some(trace) = fault {
        builder = builder.fault_plan(FaultPlan::parse_trace(trace).unwrap());
    }
    let mut engine = builder.build_host_with_mode(WeightStore::synthetic(&m, 42), mode);
    for req in workload(&m, n, 7) {
        engine.submit(req).unwrap();
    }
    let report = engine.shutdown().unwrap();
    assert_eq!(report.metrics.requests_completed, n, "requests lost");
    let mut tokens: Vec<(u64, Vec<i32>)> =
        report.responses.iter().map(|r| (r.id, r.tokens.clone())).collect();
    tokens.sort();
    tokens
}

#[test]
fn pipelined_tokens_bit_identical_across_k_plans_and_kv_layouts() {
    let n = 6;
    let configs: Vec<(&str, ServeConfig)> = vec![
        ("tp", ServeConfig::tp(4)),
        ("hap-hybrid", ServeConfig::hap_transition(4)),
        ("adaptive", ServeConfig::adaptive(4)),
    ];
    for (name, base) in &configs {
        for kv in [KvLayout::Padded, KvLayout::Paged { block_size: 8, num_blocks: 0 }] {
            let mut oracle_cfg = base.clone();
            oracle_cfg.kv = kv;
            let oracle = run_tokens(oracle_cfg.clone(), EngineMode::Sequential, None, n);
            assert!(
                oracle.iter().all(|(_, t)| !t.is_empty()),
                "{name} kv={kv:?}: oracle generated nothing"
            );
            for k in [1usize, 2, 3, 5, 8] {
                let mut cfg = oracle_cfg.clone();
                cfg.pipeline_chunks = k;
                let got = run_tokens(cfg, EngineMode::Parallel, None, n);
                assert_eq!(
                    oracle, got,
                    "{name} kv={kv:?} K={k}: pipelined tokens diverged from the \
                     sequential oracle"
                );
            }
        }
    }
}

#[test]
fn budget_driven_chunk_sizing_does_not_move_tokens() {
    // Budget sizing derives chunk lengths from measured wall-clock
    // rates — nondeterministic sizes, but chunking is exact for *any*
    // sizes, so the tokens must match the static oracle bit-for-bit.
    let n = 6;
    let oracle = run_tokens(ServeConfig::tp(4), EngineMode::Sequential, None, n);
    let mut cfg = ServeConfig::tp(4);
    cfg.pipeline_chunks = 4;
    cfg.prefill_chunk = 4;
    cfg.prefill_budget_ms = 0.5;
    let got = run_tokens(cfg, EngineMode::Parallel, None, n);
    assert_eq!(oracle, got, "budget-sized chunks changed generated tokens");
}

#[test]
fn pipelined_fault_schedules_align_with_sequential_at_same_k() {
    // Fault clocks tick on engine iterations, so the comparison holds
    // the whole config — including K — fixed and varies only the
    // executor's overlap mode. Crash traces exercise degraded re-plan +
    // replay-from-prompt recovery under chunked execution; the
    // transient trace exercises the bounded retry path mid-pipeline.
    let n = 6;
    let cases: Vec<(&str, ServeConfig)> = vec![
        ("crash@2", ServeConfig::tp(4)),
        ("crash@6", ServeConfig::hap_transition(4)),
        ("transient2@5", ServeConfig::tp(4)),
    ];
    for (trace, base) in cases {
        for k in [3usize, 8] {
            let mut cfg = base.clone();
            cfg.pipeline_chunks = k;
            let seq = run_tokens(cfg.clone(), EngineMode::Sequential, Some(trace), n);
            let par = run_tokens(cfg, EngineMode::Parallel, Some(trace), n);
            assert!(seq.iter().all(|(_, t)| !t.is_empty()), "{trace} K={k}: empty tokens");
            assert_eq!(
                seq, par,
                "{trace} K={k}: overlapped execution diverged from sequential \
                 under an identical fault schedule"
            );
        }
    }
}

#[test]
fn pipelined_runs_attribute_module_times() {
    let m = meta();
    let plan = ShardPlan::new(AttnStrategy::new(4, 1), ExpertStrategy::new(2, 2));
    let toks: Vec<i32> =
        (0..(m.batch * m.prefill_len) as i32).map(|i| i % m.vocab as i32).collect();
    let mut exec = ModelExecutor::host(WeightStore::synthetic(&m, 1));
    exec.set_pipeline_chunks(4).unwrap();
    exec.prefill(&toks, &plan).unwrap();
    let after_prefill = exec.module_times().clone();
    assert!(after_prefill.attn_s > 0.0, "attention time not attributed");
    assert!(after_prefill.expert_s > 0.0, "expert FFN time not attributed");
    assert!(after_prefill.collective_s > 0.0, "combine time not attributed");
    assert_eq!(after_prefill.per_device_s.len(), 4, "per-device table incomplete");
    assert!(after_prefill.per_device_s.iter().all(|&s| s > 0.0), "idle device recorded");

    // Decode under the pipeline keeps accumulating into the same
    // buckets: the delta since the prefill snapshot is strictly
    // positive for compute and combine.
    exec.decode_step(&vec![1; m.batch], &plan).unwrap();
    let delta = exec.module_times().delta_since(&after_prefill);
    assert!(delta.attn_s > 0.0 && delta.expert_s > 0.0 && delta.collective_s > 0.0);
}
