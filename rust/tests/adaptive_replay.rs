//! Tier-1 acceptance for the online adaptation loop (ISSUE 2): on the
//! chat→long-doc phase-shift trace, adaptive re-planning must beat the
//! static baselines, land within 10% of the free-switch oracle with
//! switch costs charged, and run >90% of batches off the plan cache.
//! Results are recorded in BENCH_adaptive_serving.json at the repo root
//! (benches/adaptive_serving.rs overwrites it with release numbers).

use hap::adapt::replay::{self, WorkloadTrace};
use hap::adapt::ControllerConfig;
use hap::config::{MoEModelConfig, NodeConfig};
use hap::planner::HapPlanner;
use hap::util::json::Json;

#[test]
fn phase_shift_adaptive_beats_static_and_tracks_oracle() {
    let model = MoEModelConfig::mixtral_8x7b();
    let node = NodeConfig::a6000x(4);
    let planner = HapPlanner::new(&model, &node);
    let trace = WorkloadTrace::phase_shift(80, 16, 17);
    let cmp = replay::compare(&planner, &trace, &ControllerConfig::default(), 32).unwrap();

    let summary = Json::obj(vec![
        ("bench", "adaptive_serving".into()),
        ("profile", "test".into()),
        ("model", model.name.as_str().into()),
        ("node", node.label().into()),
        ("phase_shift", cmp.to_json()),
    ]);
    let path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_adaptive_serving.json");
    if let Err(e) = std::fs::write(&path, summary.to_string_pretty()) {
        eprintln!("could not write {}: {e}", path.display());
    }
    println!(
        "phase-shift: adaptive {:.2}s | static-tp {:.2}s | static-first {:.2}s | oracle {:.2}s \
         | {} switches ({:.3}s) | cache {:.1}% hits",
        cmp.adaptive.total_s,
        cmp.static_tp.total_s,
        cmp.static_first.total_s,
        cmp.oracle.total_s,
        cmp.adaptive.switches,
        cmp.adaptive.switch_time_s,
        cmp.adaptive.cache_hit_rate * 100.0
    );

    // Acceptance: beats static TP end to end, switch costs charged.
    assert!(
        cmp.adaptive.total_s < cmp.static_tp.total_s * 0.999,
        "adaptive {:.3}s did not beat static TP {:.3}s",
        cmp.adaptive.total_s,
        cmp.static_tp.total_s
    );
    // Never loses to the best a-priori single plan for the first phase
    // (strictly better whenever the two phases' optima differ).
    assert!(
        cmp.adaptive.total_s <= cmp.static_first.total_s * 1.0005,
        "adaptive {:.3}s lost to the static first-phase plan {:.3}s",
        cmp.adaptive.total_s,
        cmp.static_first.total_s
    );
    // Within 10% of the per-phase oracle with free switches.
    assert!(
        cmp.adaptive.total_s <= cmp.oracle.total_s * 1.10,
        "adaptive {:.3}s is {:.1}% over the oracle {:.3}s (>10%)",
        cmp.adaptive.total_s,
        (cmp.vs_oracle() - 1.0) * 100.0,
        cmp.oracle.total_s
    );
    // Sanity: the oracle should not meaningfully lose to a fixed plan
    // it could have picked. Generous 5% slack: the ILP prices decode at
    // the single midpoint context while replay integrates it by
    // quadrature, so a plan optimal under the planner's metric can be
    // slightly off-optimal under the replay metric on the
    // decode-heavy chat phase.
    assert!(
        cmp.oracle.total_s <= cmp.static_tp.total_s * 1.05,
        "oracle {:.3}s vs static TP {:.3}s",
        cmp.oracle.total_s,
        cmp.static_tp.total_s
    );
    // Re-planning is a lookup: >90% plan-cache hit rate over the trace.
    assert!(
        cmp.adaptive.cache_hit_rate > 0.90,
        "plan cache hit rate {:.1}% <= 90%",
        cmp.adaptive.cache_hit_rate * 100.0
    );
}

#[test]
fn oscillating_trace_is_flap_damped_end_to_end() {
    // The no-thrash invariant at the harness level. With a one-tick
    // window the traffic key alternates every batch and the debounce
    // guard must block every switch; with a two-tick window the
    // alternating phases blend into one stable "mixture" key, so the
    // controller may settle onto its plan at most once — but must
    // never ping-pong.
    let model = MoEModelConfig::mixtral_8x7b();
    let node = NodeConfig::a6000x(4);
    let planner = HapPlanner::new(&model, &node);
    let points: Vec<replay::TracePoint> = (0..40)
        .map(|i| {
            let (context, generate) =
                if i % 2 == 0 { replay::CHAT_PHASE } else { replay::DOC_PHASE };
            replay::TracePoint { context, generate, batch: 16 }
        })
        .collect();
    let trace = WorkloadTrace { name: "osc-exact".into(), points };
    let strict =
        replay::replay_adaptive(&planner, &trace, &ControllerConfig::default(), 16).unwrap();
    assert_eq!(strict.switches, 0, "alternating keys thrashed weights");
    assert_eq!(strict.switch_time_s, 0.0);
    let blended =
        replay::replay_adaptive(&planner, &trace, &ControllerConfig::default(), 32).unwrap();
    assert!(
        blended.switches <= 1,
        "mixture-key oscillation ping-ponged: {} switches",
        blended.switches
    );
}
