//! Serving-pipeline end-to-end tests over the real PJRT model:
//! router → batcher → executor, with the HAP phase-specific plan.
//! Requires `make artifacts` (skips otherwise).

use hap::runtime::PjrtRuntime;
use hap::serving::{serve_workload, Request, RouterPolicy, ServeConfig};
use hap::util::rng::Rng;
use std::path::Path;

fn artifacts() -> Option<PjrtRuntime> {
    let p = Path::new("artifacts");
    if !p.join("manifest.json").exists() {
        eprintln!("skipping: artifacts/ not built");
        return None;
    }
    Some(PjrtRuntime::load(p).expect("load artifacts"))
}

fn workload(rt: &PjrtRuntime, n: usize, gen: usize, seed: u64) -> Vec<Request> {
    let m = &rt.manifest.model;
    let mut rng = Rng::new(seed);
    (0..n as u64)
        .map(|id| {
            let len = rng.range(4, m.prefill_len);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(m.vocab) as i32).collect();
            Request::new(id, prompt, gen)
        })
        .collect()
}

#[test]
fn serves_all_requests_with_exact_token_counts() {
    let Some(rt) = artifacts() else { return };
    let config = ServeConfig::tp(2);
    let report = serve_workload(&rt, &config, workload(&rt, 10, 6, 1)).unwrap();
    assert_eq!(report.metrics.requests_completed, 10);
    assert_eq!(report.responses.len(), 10);
    for r in &report.responses {
        assert_eq!(r.tokens.len(), 6, "request {} got {} tokens", r.id, r.tokens.len());
        assert!(r.latency >= r.ttft);
        assert!(r.tokens.iter().all(|&t| t >= 0 && (t as usize) < rt.manifest.model.vocab));
    }
    assert_eq!(report.metrics.tokens_generated, 60);
    assert!(report.metrics.throughput() > 0.0);
}

#[test]
fn hap_plan_and_tp_plan_generate_identical_tokens() {
    // The dynamic parallelism transition must be invisible in outputs.
    let Some(rt) = artifacts() else { return };
    let w1 = workload(&rt, 6, 5, 2);
    let w2 = workload(&rt, 6, 5, 2);
    let tp = serve_workload(&rt, &ServeConfig::tp(4), w1).unwrap();
    let hap = serve_workload(&rt, &ServeConfig::hap_transition(4), w2).unwrap();
    assert_eq!(hap.metrics.transitions, hap.metrics.batches_prefilled);
    let mut tp_tokens: Vec<(u64, Vec<i32>)> =
        tp.responses.iter().map(|r| (r.id, r.tokens.clone())).collect();
    let mut hap_tokens: Vec<(u64, Vec<i32>)> =
        hap.responses.iter().map(|r| (r.id, r.tokens.clone())).collect();
    tp_tokens.sort();
    hap_tokens.sort();
    assert_eq!(tp_tokens, hap_tokens, "transition changed generated tokens");
}

#[test]
fn partial_batches_and_multiple_batches_work() {
    let Some(rt) = artifacts() else { return };
    let b = rt.manifest.model.batch;
    // 1 more request than one batch → two batches, second partial.
    let report =
        serve_workload(&rt, &ServeConfig::tp(1), workload(&rt, b + 1, 3, 3)).unwrap();
    assert_eq!(report.metrics.requests_completed, b + 1);
    assert_eq!(report.metrics.batches_prefilled, 2);
}

#[test]
fn sjf_policy_served_and_counted() {
    let Some(rt) = artifacts() else { return };
    let mut config = ServeConfig::tp(1);
    config.policy = RouterPolicy::Sjf;
    let report = serve_workload(&rt, &config, workload(&rt, 5, 4, 4)).unwrap();
    assert_eq!(report.metrics.requests_completed, 5);
}

#[test]
fn generation_capped_by_kv_budget() {
    let Some(rt) = artifacts() else { return };
    let m = &rt.manifest.model;
    let budget = m.max_len - m.prefill_len;
    // Ask for far more than the cache allows; the batcher must cap it.
    let report =
        serve_workload(&rt, &ServeConfig::tp(1), workload(&rt, 2, budget + 50, 5)).unwrap();
    for r in &report.responses {
        assert!(r.tokens.len() <= budget, "generated past the KV budget");
    }
}
