//! Tier-1 acceptance for the paged KV-cache subsystem (ISSUE 9):
//!
//! - **allocator properties** (seeded random schedules): no block is
//!   ever double-owned, refcounts hit zero exactly when the last owner
//!   releases, and free-list reuse is deterministic — two pools driven
//!   by the same schedule allocate identical block sequences;
//! - **bit-identity**: the paged engine produces bit-identical
//!   per-request tokens to the padded engine under fixed plans, the
//!   HAP phase transition, chunked prefill, adaptive plan selection,
//!   and crash-at-k degraded recovery — the padded path is the
//!   retained equivalence reference;
//! - **COW prefix sharing**: requests with a common prompt share
//!   trie-cached blocks (prefix hits surface in metrics, registry, and
//!   trace) and the copy-on-write divergence never perturbs a
//!   sibling's tokens;
//! - **block-bound admission**: a pool too small for the whole
//!   workload backpressures (joiners wait for retirements' blocks)
//!   instead of deadlocking or over-admitting, and still completes
//!   every request bit-identically.
//!
//! Everything runs artifact-free on the host grid engine.

use hap::model::{BlockPool, FaultPlan, KvLayout, WeightStore};
use hap::obs::{MetricValue, Recorder};
use hap::runtime::TinyModelMeta;
use hap::serving::{
    serve_with_recorder, Engine, EngineState, Request, Scheduling, ServeConfig, ServeReport,
};
use hap::util::prop;
use hap::util::rng::Rng;

fn meta() -> TinyModelMeta {
    TinyModelMeta::host_demo()
}

fn weights(seed: u64) -> WeightStore {
    WeightStore::synthetic(&meta(), seed)
}

fn mixed_workload(m: &TinyModelMeta, n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n as u64)
        .map(|id| {
            let len = rng.range(m.prefill_len / 2, m.prefill_len);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(m.vocab) as i32).collect();
            let gen = rng.range(2, 8);
            Request::new(id, prompt, gen)
        })
        .collect()
}

/// Every request shares one system prompt (same padded row → trie hit
/// after the first admission lands it).
fn shared_prompt_workload(m: &TinyModelMeta, n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    let prompt: Vec<i32> =
        (0..m.prefill_len - 2).map(|_| rng.below(m.vocab) as i32).collect();
    (0..n as u64).map(|id| Request::new(id, prompt.clone(), 4)).collect()
}

fn sorted_tokens(report: &ServeReport) -> Vec<(u64, Vec<i32>)> {
    let mut t: Vec<(u64, Vec<i32>)> =
        report.responses.iter().map(|r| (r.id, r.tokens.clone())).collect();
    t.sort();
    t
}

fn paged(mut config: ServeConfig, block_size: usize, num_blocks: usize) -> ServeConfig {
    config.kv = KvLayout::Paged { block_size, num_blocks };
    config
}

fn run(config: ServeConfig, wseed: u64, workload: Vec<Request>) -> ServeReport {
    let mut engine = Engine::builder(config).build_host(weights(wseed));
    for req in workload {
        engine.submit(req).unwrap();
    }
    engine.shutdown().unwrap()
}

// ---- allocator properties ----------------------------------------------

#[test]
fn prop_pool_never_double_owns_and_frees_exactly_at_zero() {
    let cases = prop::default_cases().min(64);
    prop::check("paged-pool-ownership", cases, |rng| {
        let n = rng.range(2, 24);
        let mut pool = BlockPool::new(n);
        // Mirror of expected refcounts, maintained independently.
        let mut refs = vec![0u32; n];
        for _ in 0..rng.range(20, 200) {
            match rng.below(3) {
                0 => {
                    if let Some(b) = pool.alloc() {
                        if refs[b] != 0 {
                            return Err(format!("alloc handed out owned block {b}"));
                        }
                        refs[b] = 1;
                    } else if refs.iter().all(|&r| r == 0) {
                        return Err("alloc failed with every block free".into());
                    }
                }
                1 => {
                    let owned: Vec<usize> =
                        (0..n).filter(|&b| refs[b] > 0).collect();
                    if let Some(&b) = owned.get(rng.below(owned.len().max(1))) {
                        pool.retain(b);
                        refs[b] += 1;
                    }
                }
                _ => {
                    let owned: Vec<usize> =
                        (0..n).filter(|&b| refs[b] > 0).collect();
                    if let Some(&b) = owned.get(rng.below(owned.len().max(1))) {
                        let freed = pool.release(b);
                        refs[b] -= 1;
                        if freed != (refs[b] == 0) {
                            return Err(format!(
                                "block {b} freed={freed} but mirror refcount {}",
                                refs[b]
                            ));
                        }
                    }
                }
            }
            for b in 0..n {
                if pool.refcount(b) != refs[b] {
                    return Err(format!(
                        "block {b}: pool refcount {} != mirror {}",
                        pool.refcount(b),
                        refs[b]
                    ));
                }
            }
            let owned = refs.iter().filter(|&&r| r > 0).count();
            if pool.in_use() != owned || pool.free_blocks() != n - owned {
                return Err(format!(
                    "accounting drifted: in_use {} free {} vs {} owned of {n}",
                    pool.in_use(),
                    pool.free_blocks(),
                    owned
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pool_allocation_order_is_deterministic() {
    let cases = prop::default_cases().min(64);
    prop::check("paged-pool-determinism", cases, |rng| {
        let n = rng.range(2, 16);
        // Drive two pools with one recorded schedule: identical
        // alloc/release streams must produce identical block ids.
        let schedule: Vec<usize> = (0..rng.range(20, 120)).map(|_| rng.below(2)).collect();
        let mut drive = |pool: &mut BlockPool| -> Vec<Option<usize>> {
            let mut held: Vec<usize> = Vec::new();
            let mut got = Vec::new();
            for &op in &schedule {
                if op == 0 {
                    let b = pool.alloc();
                    if let Some(b) = b {
                        held.push(b);
                    }
                    got.push(b);
                } else if let Some(b) = held.pop() {
                    pool.release(b);
                }
            }
            got
        };
        let a = drive(&mut BlockPool::new(n));
        let b = drive(&mut BlockPool::new(n));
        if a != b {
            return Err("identical schedules diverged".into());
        }
        Ok(())
    });
}

#[test]
fn pool_reuses_freed_blocks_lifo() {
    // A fresh pool hands out ascending ids; the most recently freed
    // block is reused first (deterministic re-admission layout).
    let mut pool = BlockPool::new(4);
    assert_eq!(pool.alloc(), Some(0));
    assert_eq!(pool.alloc(), Some(1));
    assert_eq!(pool.alloc(), Some(2));
    pool.release(1);
    assert_eq!(pool.alloc(), Some(1), "freed block not reused first");
    assert_eq!(pool.alloc(), Some(3));
    assert_eq!(pool.alloc(), None, "pool of 4 handed out a 5th block");
}

// ---- bit-identity against the padded reference -------------------------

#[test]
fn paged_tokens_bit_identical_across_fixed_plans() {
    let m = meta();
    let workload = mixed_workload(&m, 10, 5);
    for config in [ServeConfig::tp(4), ServeConfig::hap_transition(4)] {
        let reference = run(config.clone(), 42, workload.clone());
        // Auto pool (num_blocks = 0): the padded-equal memory budget.
        let report = run(paged(config.clone(), 8, 0), 42, workload.clone());
        assert_eq!(report.metrics.requests_completed, workload.len());
        assert_eq!(
            sorted_tokens(&reference),
            sorted_tokens(&report),
            "paged tokens diverged from padded under {}",
            config.label()
        );
    }
}

#[test]
fn paged_tokens_bit_identical_with_chunked_prefill() {
    let m = meta();
    let workload = mixed_workload(&m, 8, 11);
    let reference = run(ServeConfig::tp(4), 7, workload.clone());
    for chunk in [4, 8] {
        let mut config = paged(ServeConfig::tp(4), 8, 0);
        config.prefill_chunk = chunk;
        let report = run(config, 7, workload.clone());
        assert_eq!(
            sorted_tokens(&reference),
            sorted_tokens(&report),
            "paged + prefill_chunk={chunk} diverged from padded unchunked"
        );
    }
}

#[test]
fn paged_tokens_bit_identical_under_adaptive_plans() {
    let m = meta();
    let workload = mixed_workload(&m, 10, 3);
    let reference = run(ServeConfig::adaptive(4), 42, workload.clone());
    let report = run(paged(ServeConfig::adaptive(4), 8, 0), 42, workload.clone());
    assert_eq!(
        sorted_tokens(&reference),
        sorted_tokens(&report),
        "paged tokens diverged from padded under adaptive plan selection"
    );
}

#[test]
fn paged_crash_recovery_bit_identical_to_unfaulted_degraded_grid() {
    let m = meta();
    let n = 8usize;
    // Reference: padded, unfaulted, on the 2-device grid the faulted
    // engine degrades to (tokens are plan-invariant, so this covers
    // pre-crash completions too).
    let reference = run(ServeConfig::tp(2), 42, mixed_workload(&m, n, 5));

    let mut engine = Engine::builder(paged(ServeConfig::tp(4), 8, 0))
        .fault_plan(FaultPlan::parse_trace("crash@3").unwrap())
        .build_host(weights(42));
    for req in mixed_workload(&m, n, 5) {
        engine.submit(req).unwrap();
    }
    engine.run_to_completion().unwrap();
    assert_eq!(engine.state(), EngineState::Degraded { devices: 2 });
    assert!(!engine.recovered().is_empty(), "crash@3 recovered no in-flight request");
    let report = engine.shutdown().unwrap();
    assert_eq!(report.metrics.requests_completed, n);
    assert_eq!(report.metrics.requests_failed, 0);
    assert_eq!(
        sorted_tokens(&reference),
        sorted_tokens(&report),
        "paged crash recovery changed generated tokens"
    );
}

// ---- COW prefix sharing ------------------------------------------------

#[test]
fn shared_prompts_hit_the_prefix_trie_without_perturbing_tokens() {
    let m = meta();
    let workload = shared_prompt_workload(&m, 12, 9);
    let reference = run(ServeConfig::tp(4), 42, workload.clone());

    let mut exec = hap::model::ModelExecutor::host(weights(42));
    let report = serve_with_recorder(
        &mut exec,
        &paged(ServeConfig::tp(4), 8, 0),
        Scheduling::Streaming,
        workload.clone(),
        Recorder::new(),
    )
    .unwrap();

    // COW on the shared blocks never perturbs a sibling: every
    // request's tokens match the padded run exactly.
    assert_eq!(
        sorted_tokens(&reference),
        sorted_tokens(&report),
        "prefix sharing changed generated tokens"
    );
    // The first admission registers the prompt; later ones hit it.
    assert!(
        report.metrics.prefix_hits > 0,
        "identical prompts never hit the prefix trie"
    );
    assert!(report.metrics.prefix_shared_tokens as usize >= m.prefill_len - 1);
    // The counters surface through the registry...
    match report.telemetry.get("prefix_hits") {
        Some(MetricValue::Counter(c)) => assert_eq!(*c, report.metrics.prefix_hits),
        other => panic!("prefix_hits missing from registry: {other:?}"),
    }
    assert!(report.telemetry.get("kv_blocks_in_use").is_some());
    assert!(report.telemetry.get("kv_blocks_free").is_some());
    // ...and block-level events land in the deterministic trace.
    let names: Vec<&str> = report.trace.iter().map(|e| e.kind.name()).collect();
    assert!(names.contains(&"BlockAlloc"), "no BlockAlloc event in trace");
    assert!(names.contains(&"BlockFree"), "no BlockFree event in trace");
    assert!(names.contains(&"PrefixHit"), "no PrefixHit event in trace");
}

// ---- block-bound admission ---------------------------------------------

#[test]
fn small_pool_backpressures_and_still_completes_bit_identically() {
    let m = meta();
    let workload = mixed_workload(&m, 10, 13);
    let reference = run(ServeConfig::tp(4), 42, workload.clone());
    // Each request reserves ceil((16 + gen<=8)/8) = 3 blocks; 7 blocks
    // admit at most 2 concurrently (the slot count alone would admit
    // 4). Admission must wait for retirements' blocks — no deadlock,
    // no over-admission, identical tokens.
    let report = run(paged(ServeConfig::tp(4), 8, 7), 42, workload.clone());
    assert_eq!(report.metrics.requests_completed, workload.len());
    assert_eq!(
        sorted_tokens(&reference),
        sorted_tokens(&report),
        "block-bound admission changed generated tokens"
    );
}

#[test]
fn paged_engine_rejects_a_pool_smaller_than_one_sequence() {
    // max_len 48 at block_size 8 needs a 6-block table; a 4-block pool
    // cannot hold one sequence and must fail fast at session start,
    // not deadlock in admission.
    let workload = mixed_workload(&meta(), 2, 1);
    let mut engine = Engine::builder(paged(ServeConfig::tp(4), 8, 4)).build_host(weights(42));
    let mut failed = false;
    for req in workload {
        if engine.submit(req).is_err() {
            failed = true;
            break;
        }
    }
    failed = failed || engine.run_to_completion().is_err() || engine.shutdown().is_err();
    assert!(failed, "undersized pool was accepted");
}
