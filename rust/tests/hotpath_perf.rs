//! Before/after measurement of the planner hot path, run as part of
//! tier-1 so BENCH_perf_hotpath.json at the repo root tracks the perf
//! trajectory on every test run (benches/perf_hotpath.rs overwrites it
//! with release-profile numbers when executed).
//!
//! "Before" is the pre-change code path kept in-tree for exactly this
//! purpose: serial scalar cost tables over uncached forest walks plus
//! the reference ILP solver (`plan_reference`). "After" is the
//! production path: batched/parallel cost tables plus the
//! flattened-tableau solver (`plan`).

use hap::config::{MoEModelConfig, NodeConfig, Scenario};
use hap::planner::{HapPlanner, PLANNER_SEED};
use hap::sim::LatencyModel;
use hap::util::json::Json;
use std::sync::Arc;
use std::time::Instant;

fn median_secs(iters: usize, mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

#[test]
fn plan_hotpath_speedup_measured_and_recorded() {
    let model = MoEModelConfig::mixtral_8x7b();
    let node = NodeConfig::a100x(8);
    let sc = Scenario::long_extended();

    // Reference planner: memo disabled reproduces the pre-batching
    // scalar path exactly.
    let mut lm = LatencyModel::train(&node.gpu, PLANNER_SEED);
    lm.set_memo_enabled(false);
    let base = HapPlanner::with_latency(&model, &node, Arc::new(lm));
    let planner = HapPlanner::new(&model, &node);

    // Both paths must select the same plan before timing means anything.
    let fast = planner.plan(&sc, sc.generate).unwrap();
    let slow = base.plan_reference(&sc).unwrap();
    assert_eq!(fast.signature(), slow.signature(), "paths disagree on the plan");
    let rel = (fast.predicted_total - slow.predicted_total).abs() / slow.predicted_total;
    assert!(rel < 1e-9, "objectives diverge: {} vs {}", fast.predicted_total, slow.predicted_total);

    let before = median_secs(5, || {
        std::hint::black_box(base.plan_reference(&sc).unwrap().predicted_total);
    });
    let after = median_secs(5, || {
        std::hint::black_box(planner.plan(&sc, sc.generate).unwrap().predicted_total);
    });
    let speedup = before / after;

    let summary = Json::obj(vec![
        ("bench", "perf_hotpath".into()),
        ("profile", "test".into()),
        (
            "planner_full_plan",
            Json::obj(vec![
                ("before_median_s", before.into()),
                ("after_median_s", after.into()),
                ("speedup", speedup.into()),
            ]),
        ),
    ]);
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_perf_hotpath.json");
    if let Err(e) = std::fs::write(&path, summary.to_string_pretty()) {
        eprintln!("could not write {}: {e}", path.display());
    }
    println!(
        "planner full plan(): before {before:.4}s, after {after:.4}s → {speedup:.2}x (recorded)"
    );

    // Wall-clock asserts are flaky on loaded shared runners, so tier-1
    // only records; set HAP_ENFORCE_PERF=1 to make the floor hard. The
    // release-profile bench (`cargo bench --bench perf_hotpath`)
    // enforces the full 3x acceptance bar.
    if std::env::var("HAP_ENFORCE_PERF").is_ok() {
        assert!(
            speedup > 1.3,
            "hot-path rewrite should clearly beat the reference: {speedup:.2}x"
        );
    } else if speedup <= 1.3 {
        eprintln!("warning: measured speedup only {speedup:.2}x (load? see BENCH_perf_hotpath.json)");
    }
}
