//! Property-based invariant tests (seeded random-input harness from
//! `hap::util::prop` — the offline stand-in for proptest).

use hap::cluster::imbalance;
use hap::config::{MoEModelConfig, NodeConfig, Scenario};
use hap::ilp::{solve, LinExpr, Problem, Sense};
use hap::quant::{self, Scheme};
use hap::sim::comm::{layer_comm_bytes, layer_comm_events};
use hap::sim::flops::{attention_cost, expert_cost, Stage};
use hap::sim::forest::{reference::ArenaForest, ForestParams, RandomForest};
use hap::strategy::{space::power_of_two_divisors, AttnStrategy, ExpertStrategy, SearchSpace};
use hap::util::prop;
use hap::util::rng::Rng;

fn random_model(rng: &mut Rng) -> MoEModelConfig {
    let mut m = MoEModelConfig::mixtral_8x7b();
    m.q_heads = [8, 16, 32][rng.below(3)];
    m.kv_heads = m.q_heads / [1, 2, 4][rng.below(3)];
    m.hidden = [2048, 4096][rng.below(2)] as usize;
    m.head_dim = 128;
    m.num_experts = [8, 16, 64][rng.below(3)];
    m.top_k = rng.range(1, 4);
    m.moe_inter_size = [1408, 2560, 14336][rng.below(3)];
    m.layers = rng.range(2, 48);
    m
}

/// ILP solver vs brute force on random HAP-shaped instances.
#[test]
fn prop_ilp_matches_bruteforce_on_hap_shaped_problems() {
    prop::check("ilp-vs-brute", 40, |rng| {
        let ka = rng.range(2, 4);
        let ke = rng.range(2, 4);
        let mut p = Problem::new();
        let s = p.binaries("s", ka);
        let ei = p.binaries("ei", ke);
        let ej = p.binaries("ej", ke);
        p.exactly_one("s", &s);
        p.exactly_one("ei", &ei);
        p.exactly_one("ej", &ej);
        for g in [&s, &ei, &ej] {
            for &v in g.iter() {
                p.set_objective_term(v, rng.range_f64(0.1, 10.0));
            }
        }
        for (i, &a) in ei.iter().enumerate() {
            for (j, &b) in ej.iter().enumerate() {
                let y = p.and_var(&format!("y{i}{j}"), a, b);
                p.set_objective_term(y, rng.range_f64(0.0, 2.0));
            }
        }
        // Random forbidden pairs (memory constraints).
        for (k, &a) in s.iter().enumerate() {
            for (i, &b) in ei.iter().enumerate() {
                if rng.chance(0.15) {
                    p.constrain(
                        &format!("mem{k}{i}"),
                        LinExpr::new().term(a, 1.0).term(b, 1.0),
                        Sense::Le,
                        1.0,
                    );
                }
            }
        }
        // Brute force over one-hot triples (AND vars determined).
        let mut best: Option<f64> = None;
        for k in 0..ka {
            for i in 0..ke {
                for j in 0..ke {
                    let mut x = vec![0.0; p.num_vars];
                    x[s[k].0] = 1.0;
                    x[ei[i].0] = 1.0;
                    x[ej[j].0] = 1.0;
                    // AND vars: y_ij = ei_i ∧ ej_j in construction order.
                    let y_base = ka + 2 * ke;
                    x[y_base + i * ke + j] = 1.0;
                    if p.feasible(&x, 1e-9) {
                        let obj = p.objective_value(&x);
                        if best.map_or(true, |b| obj < b) {
                            best = Some(obj);
                        }
                    }
                }
            }
        }
        let got = solve(&p).optimal().map(|(_, o)| o);
        match (best, got) {
            (Some(b), Some(g)) => {
                prop_ok((g - b).abs() < 1e-6, format!("brute {b} vs ilp {g}"))
            }
            (None, None) => Ok(()),
            (b, g) => Err(format!("feasibility mismatch: {b:?} vs {g:?}")),
        }
    });
}

fn prop_ok(cond: bool, msg: String) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg)
    }
}

/// Search-space constraint satisfaction (eq. 5) for random models.
#[test]
fn prop_search_space_respects_eq5() {
    prop::check("space-eq5", 60, |rng| {
        let m = random_model(rng);
        let n = [4usize, 8][rng.below(2)];
        let node = if rng.chance(0.5) {
            NodeConfig::a100x(n)
        } else {
            NodeConfig::new(hap::config::GpuSpec::a6000(), n)
        };
        let sc = Scenario::table2()[rng.below(4)].clone();
        let space = SearchSpace::enumerate(&m, &node, &sc);
        for a in &space.attn {
            prop_ok(a.tp * a.dp == n, format!("attn {} devices", a.label()))?;
            prop_ok(m.q_heads % a.tp == 0, format!("heads % {}", a.tp))?;
            prop_ok(a.tp.is_power_of_two(), "tp pow2".into())?;
        }
        for e in &space.expert {
            prop_ok(e.tp * e.ep == n, format!("expert {} devices", e.label()))?;
            prop_ok(m.num_experts % e.ep == 0, format!("experts % {}", e.ep))?;
            prop_ok(m.moe_inter_size % e.tp == 0, format!("inter % {}", e.tp))?;
        }
        Ok(())
    });
}

/// FLOPs conservation: per-device work × devices ≈ total work for TP
/// and balanced EP (no sharding should create or destroy FLOPs beyond
/// the replicated gate).
#[test]
fn prop_flops_conservation() {
    prop::check("flops-conservation", 60, |rng| {
        let m = random_model(rng);
        let batch = rng.range(1, 64);
        let seq = [128usize, 512, 2048][rng.below(3)];
        let stage = if rng.chance(0.5) { Stage::Prefill } else { Stage::Decode };
        let full = expert_cost(&m, &ExpertStrategy::new(1, 1), stage, batch, seq, 1.0);
        for n in [2usize, 4] {
            if m.num_experts % n != 0 || m.moe_inter_size % n != 0 {
                continue;
            }
            let tp = expert_cost(&m, &ExpertStrategy::new(n, 1), stage, batch, seq, 1.0);
            let ep = expert_cost(&m, &ExpertStrategy::new(1, n), stage, batch, seq, 1.0);
            let rel_tp = (tp.flops * n as f64 - full.flops).abs() / full.flops;
            let rel_ep = (ep.flops * n as f64 - full.flops).abs() / full.flops;
            // Gate is replicated across shards → small over-count allowed.
            prop_ok(rel_tp < 0.05, format!("tp{n} rel {rel_tp}"))?;
            prop_ok(rel_ep < 0.05, format!("ep{n} rel {rel_ep}"))?;
        }
        let a_full = attention_cost(&m, &AttnStrategy::new(1, 1), stage, batch, seq);
        for n in [2usize, 4] {
            if m.q_heads % n != 0 {
                continue;
            }
            let a_tp = attention_cost(&m, &AttnStrategy::new(n, 1), stage, batch, seq);
            let rel = (a_tp.flops * n as f64 - a_full.flops).abs() / a_full.flops;
            // KV replication under GQA allows a modest over-count.
            prop_ok(rel < 0.35, format!("attn tp{n} rel {rel}"))?;
        }
        Ok(())
    });
}

/// Comm volumes are non-negative, zero on one device, and monotone in
/// token count.
#[test]
fn prop_comm_volume_sanity() {
    prop::check("comm-sanity", 60, |rng| {
        let m = random_model(rng);
        let batch = rng.range(1, 32);
        let seq = rng.range(64, 4096);
        let n = 4;
        let strategies: Vec<(AttnStrategy, ExpertStrategy)> = vec![
            (AttnStrategy::new(n, 1), ExpertStrategy::new(n, 1)),
            (AttnStrategy::new(1, n), ExpertStrategy::new(1, n)),
            (AttnStrategy::new(2, 2), ExpertStrategy::new(2, 2)),
        ];
        for (a, e) in &strategies {
            if m.q_heads % a.tp != 0 || m.num_experts % e.ep != 0 || m.moe_inter_size % e.tp != 0 {
                continue;
            }
            let small = layer_comm_bytes(&layer_comm_events(&m, a, e, Stage::Prefill, batch, seq));
            let big =
                layer_comm_bytes(&layer_comm_events(&m, a, e, Stage::Prefill, batch, seq * 2));
            prop_ok(small >= 0.0 && big >= small, format!("monotone {} {}", a.label(), e.label()))?;
        }
        let none = layer_comm_events(
            &m,
            &AttnStrategy::new(1, 1),
            &ExpertStrategy::new(1, 1),
            Stage::Prefill,
            batch,
            seq,
        );
        prop_ok(none.is_empty(), "single device must not communicate".into())
    });
}

/// INT4 round trip: error bounded by half the block scale, for every
/// scheme and random shapes.
#[test]
fn prop_quant_round_trip_error_bound() {
    prop::check("quant-bound", 40, |rng| {
        let rows = rng.range(1, 32);
        let group = [32usize, 64, 128][rng.below(3)];
        let cols = group * rng.range(1, 4);
        let data: Vec<f32> = (0..rows * cols)
            .map(|_| (rng.gauss() as f32) * rng.range_f64(0.001, 0.1) as f32)
            .collect();
        for scheme in
            [Scheme::PerTensor, Scheme::PerChannel, Scheme::PerGroup { group_size: group }]
        {
            let q = quant::quantize(&data, rows, cols, scheme);
            let deq = quant::dequantize(&q);
            for (i, (&x, &y)) in data.iter().zip(&deq).enumerate() {
                let s = q.scales[i / q.block_len];
                if (x - y).abs() > s * 0.5 + 1e-6 {
                    return Err(format!("{}: elem {i} err {} scale {s}", scheme.name(), (x - y).abs()));
                }
            }
        }
        Ok(())
    });
}

/// Imbalance model: ≥ 1 always, → 1 with many tokens, grows with skew.
#[test]
fn prop_imbalance_limits() {
    prop::check("imbalance", 60, |rng| {
        let experts = [8usize, 16, 60, 64][rng.below(4)];
        let ep = [2usize, 4][rng.below(2)];
        if experts % ep != 0 {
            return Ok(());
        }
        let top_k = rng.range(1, 4);
        let few = imbalance::expected_imbalance(experts, ep, rng.range(1, 32), top_k, 0.3);
        let many = imbalance::expected_imbalance(experts, ep, 1_000_000, top_k, 0.3);
        prop_ok(few >= 1.0 && many >= 1.0, "imbalance >= 1".into())?;
        prop_ok(few >= many - 1e-9, format!("few {few} < many {many}"))?;
        let flat = imbalance::expected_imbalance(experts, ep, 1_000_000, top_k, 0.0);
        prop_ok(flat < 1.05, format!("uniform large-token imbalance {flat}"))?;
        Ok(())
    });
}

/// Draw a random regression problem + forest hyperparameters.
fn random_forest_setup(rng: &mut Rng) -> (Vec<Vec<f64>>, Vec<f64>, ForestParams) {
    let n = rng.range(20, 200);
    let dim = rng.range(1, 6);
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for _ in 0..n {
        let row: Vec<f64> = (0..dim).map(|_| rng.range_f64(-5.0, 5.0)).collect();
        ys.push(row.iter().sum::<f64>().sin() + 0.1 * row[0]);
        xs.push(row);
    }
    let params = ForestParams {
        n_trees: rng.range(1, 16),
        max_depth: rng.range(2, 10),
        min_split: rng.range(2, 6),
        max_features: if rng.chance(0.3) { Some(rng.range(1, dim)) } else { None },
        seed: rng.next_u64(),
    };
    (xs, ys, params)
}

/// `predict_batch` must be bit-identical to per-row `predict` — the
/// planner's vectorized cost tables rely on this equivalence.
#[test]
fn prop_forest_predict_batch_bit_identical_to_scalar() {
    prop::check("forest-batch", 25, |rng| {
        let (xs, ys, params) = random_forest_setup(rng);
        let dim = xs[0].len();
        let forest = RandomForest::fit(&xs, &ys, &params);
        let queries: Vec<Vec<f64>> = (0..rng.range(1, 64))
            .map(|_| (0..dim).map(|_| rng.range_f64(-6.0, 6.0)).collect())
            .collect();
        let batch = forest.predict_batch(&queries);
        prop_ok(batch.len() == queries.len(), "batch length".into())?;
        for (x, b) in queries.iter().zip(&batch) {
            let s = forest.predict(x);
            if s.to_bits() != b.to_bits() {
                return Err(format!("scalar {s:?} vs batch {b:?} for {x:?}"));
            }
        }
        Ok(())
    });
}

/// The flattened SoA forest must reproduce the enum-arena reference
/// forest exactly under the same seed (same RNG stream, same trees).
#[test]
fn prop_soa_forest_matches_arena_reference() {
    prop::check("forest-soa-vs-arena", 25, |rng| {
        let (xs, ys, params) = random_forest_setup(rng);
        let dim = xs[0].len();
        let arena = ArenaForest::fit(&xs, &ys, &params);
        let soa = RandomForest::fit(&xs, &ys, &params);
        prop_ok(arena.n_trees() == soa.n_trees(), "tree count".into())?;
        for _ in 0..32 {
            let x: Vec<f64> = (0..dim).map(|_| rng.range_f64(-6.0, 6.0)).collect();
            let a = arena.predict(&x);
            let s = soa.predict(&x);
            if a.to_bits() != s.to_bits() {
                return Err(format!("arena {a:?} vs soa {s:?} for {x:?}"));
            }
        }
        Ok(())
    });
}

/// Power-of-two divisor enumeration is exact.
#[test]
fn prop_pow2_divisors() {
    prop::check("pow2", 20, |rng| {
        let n = 1usize << rng.below(7);
        let d = power_of_two_divisors(n);
        prop_ok(
            d.iter().all(|x| n % x == 0) && d.len() == (n.trailing_zeros() as usize + 1),
            format!("{n}: {d:?}"),
        )
    });
}

/// Plan cache: hits are bit-identical to a fresh solve of the same
/// quantized key, and a platform change invalidates instead of serving
/// a stale plan.
#[test]
fn prop_plan_cache_bit_identical_and_platform_safe() {
    use hap::adapt::{PlanCache, QuantizedScenario};
    use hap::planner::HapPlanner;
    let m = MoEModelConfig::mixtral_8x7b();
    let pcie = NodeConfig::a6000x(4);
    let nvlink = NodeConfig::a100x(4);
    prop::check("plan-cache", 10, |rng| {
        let base = Scenario::table2()[rng.below(4)].clone();
        let sc = base.with_batch([8, 16, 32][rng.below(3)]);
        let key = QuantizedScenario::from_scenario(&sc);
        let mut cache = PlanCache::new();
        let planner = HapPlanner::new(&m, &pcie);
        let missed = cache.plan(&planner, key).map_err(|e| e.to_string())?;
        let hit = cache.plan(&planner, key).map_err(|e| e.to_string())?;
        prop_ok(cache.hits == 1 && cache.misses == 1, "hit/miss accounting".into())?;
        let rep = key.to_scenario();
        let fresh = planner.plan(&rep, rep.generate).map_err(|e| e.to_string())?;
        for (name, plan) in [("hit", &hit), ("fresh", &fresh)] {
            prop_ok(
                plan.signature() == missed.signature(),
                format!("{name} signature {} vs {}", plan.signature(), missed.signature()),
            )?;
            prop_ok(
                plan.predicted_total.to_bits() == missed.predicted_total.to_bits(),
                format!("{name} objective differs"),
            )?;
        }
        // Platform swap: the cached PCIe plan must not leak through.
        let other = HapPlanner::new(&m, &nvlink);
        let swapped = cache.plan(&other, key).map_err(|e| e.to_string())?;
        prop_ok(cache.invalidations == 1, "platform change must invalidate".into())?;
        prop_ok(swapped.node == nvlink.label(), "plan carries the new platform".into())?;
        Ok(())
    });
}

/// Controller no-thrash invariant: every Switch decision satisfies
/// projected savings ≥ breakeven_factor × switch cost, and when the
/// cost structurally exceeds any projectable savings there are zero
/// switches.
#[test]
fn prop_controller_switch_economics() {
    use hap::adapt::{ControllerConfig, QuantizedScenario, SwitchController, SwitchDecision};
    use hap::planner::HybridPlan;
    use hap::sim::latency::ModuleLatency;
    use hap::transition::{TransitionCost, TransitionMethod};

    fn dummy_plan(pre_ep: usize, dec_ep: usize) -> HybridPlan {
        HybridPlan {
            model: "prop".into(),
            node: "4xProp".into(),
            scenario: Scenario::short_constrained(),
            attn: AttnStrategy::new(4, 1),
            expert_prefill: ExpertStrategy::new(4 / pre_ep, pre_ep),
            expert_decode: ExpertStrategy::new(4 / dec_ep, dec_ep),
            transition: TransitionCost {
                method: TransitionMethod::None,
                overhead: 0.0,
                raw_pipeline: 0.0,
                reshard: 0.0,
            },
            pipelined_prefill: false,
            pipelined_decode: false,
            predicted_prefill: ModuleLatency::default(),
            predicted_decode: ModuleLatency::default(),
            predicted_total: 1.0,
            solve_time: 0.0,
            k_a: 1,
            k_e: 1,
        }
    }

    prop::check("controller-economics", 64, |rng| {
        let factor = rng.range_f64(1.0, 4.0);
        let config = ControllerConfig {
            breakeven_factor: factor,
            confirm_batches: rng.range(1, 3),
            cooldown_batches: rng.range(0, 6),
            ..Default::default()
        };
        let mut c = SwitchController::new(config);
        let plans = [dummy_plan(1, 1), dummy_plan(4, 1), dummy_plan(2, 2)];
        let keys = [
            QuantizedScenario { context: 256, generate: 2048, batch: 16 },
            QuantizedScenario { context: 4096, generate: 64, batch: 16 },
            QuantizedScenario { context: 1024, generate: 256, batch: 8 },
        ];
        for _ in 0..rng.range(20, 120) {
            let key = keys[rng.below(3)];
            let cand = &plans[rng.below(3)];
            let active_lat = rng.range_f64(0.1, 10.0);
            let cand_lat = rng.range_f64(0.1, 10.0);
            let cost = rng.range_f64(0.0, 5.0);
            let dwell_before = c.expected_dwell();
            match c.step(key, cand, active_lat, cand_lat, cost) {
                SwitchDecision::Switch { projected_savings, cost: charged } => {
                    // Invariant: savings projected over the dwell
                    // estimate in force at decision time must clear the
                    // safety factor.
                    let expect = (active_lat - cand_lat) * dwell_before;
                    prop_ok(
                        (projected_savings - expect).abs() <= 1e-9 * expect.abs().max(1.0),
                        format!("savings {projected_savings} != gain×dwell {expect}"),
                    )?;
                    prop_ok(
                        projected_savings >= factor * charged - 1e-12,
                        format!(
                            "switched below break-even: {projected_savings} < {factor}×{charged}"
                        ),
                    )?;
                }
                SwitchDecision::Adopt | SwitchDecision::Stay => {}
            }
        }
        // Structural zero-switch case: cost beyond any projectable gain.
        let mut never = SwitchController::new(ControllerConfig {
            breakeven_factor: factor,
            confirm_batches: 1,
            cooldown_batches: 0,
            ..Default::default()
        });
        let huge = 10.0 * never.expected_dwell().max(4096.0) * 10.0;
        never.step(keys[0], &plans[0], f64::INFINITY, 1.0, 0.0);
        for i in 0..40 {
            let key = keys[1 + (i % 2)];
            let d = never.step(key, &plans[1], 10.0, 0.1, huge);
            prop_ok(
                !matches!(d, SwitchDecision::Switch { .. }),
                "switched when cost exceeds any projected savings".into(),
            )?;
        }
        prop_ok(never.switches == 0, "no-thrash violated".into())
    });
}
