//! End-to-end runtime tests: load the AOT artifacts through PJRT and
//! verify that the grid engine's composition of per-device artifacts is
//! numerically consistent across parallel strategies.
//!
//! Strategy-invariance is the core correctness property of the whole
//! stack: TP1 (single device, no sharding) must produce the same
//! logits as every other grid — TP2/TP4 attention × TP/EP/EP×TP
//! experts — because the sharding + collectives are mathematically
//! exact re-partitionings. A failure anywhere — kernel, lowering,
//! manifest, weight slicing, combine — breaks the equality.
//!
//! (The same invariances are asserted runtime-free on the host backend
//! in rust/tests/grid_engine.rs; this suite exercises the PJRT path.)
//!
//! Requires `make artifacts` (skipped with a message otherwise).

use hap::model::{ModelExecutor, ShardPlan};
use hap::runtime::literal::argmax_rows;
use hap::runtime::PjrtRuntime;
use hap::strategy::{AttnStrategy, ExpertStrategy};
use std::path::Path;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        None
    }
}

fn test_tokens(rt: &PjrtRuntime) -> Vec<i32> {
    let m = &rt.manifest.model;
    // Deterministic pseudo-prompt.
    (0..m.batch * m.prefill_len)
        .map(|i| ((i * 37 + 11) % m.vocab) as i32)
        .collect()
}

fn plan(attn_tp: usize, expert_tp: usize, expert_ep: usize) -> ShardPlan {
    let n = attn_tp.max(expert_tp * expert_ep);
    ShardPlan::new(
        AttnStrategy::new(attn_tp, n / attn_tp),
        ExpertStrategy::new(expert_tp, expert_ep),
    )
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn artifacts_load_and_have_expected_entries() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(dir).expect("load artifacts");
    for name in [
        "attn_prefill_tp1",
        "attn_prefill_tp4",
        "attn_decode_tp2",
        "expert_prefill_tp4",
        "expert_decode_ep4",
        "expert_prefill_ep2",
        "embed_prefill",
        "embed_decode",
        "head",
    ] {
        assert!(rt.has(name), "missing artifact {name}");
    }
    assert_eq!(rt.manifest.model.hidden, 256);
}

#[test]
fn prefill_logits_invariant_across_strategies() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(dir).expect("load artifacts");
    let tokens = test_tokens(&rt);

    let mut base_exec = ModelExecutor::new(&rt).unwrap();
    let base = base_exec.prefill(&tokens, &ShardPlan::tp(1)).unwrap();

    let variants = [
        ShardPlan::tp(2),
        ShardPlan::tp(4),
        plan(4, 1, 4), // attn TP4, experts EP4
        plan(2, 1, 2),
        plan(1, 4, 1), // attn TP1 (DP4 groups), experts TP4
        // Hybrid EP2×TP2 experts on the 4-device grid: runs the
        // EP-family artifact on inter-padded shards — must be exact.
        plan(4, 2, 2),
        // DP×TP attention: each DP group runs the padded sub-batch.
        ShardPlan::new(AttnStrategy::new(2, 2), ExpertStrategy::new(4, 1)),
    ];
    for v in variants {
        let mut exec = ModelExecutor::new(&rt).unwrap();
        let got = exec.prefill(&tokens, &v).unwrap();
        let d = max_abs_diff(&base.data, &got.data);
        assert!(
            d < 1e-3,
            "strategy {} diverges from TP1: max|Δ|={d}",
            v.label()
        );
    }
}

#[test]
fn greedy_decode_consistent_and_transition_preserves_tokens() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(dir).expect("load artifacts");
    let tokens = test_tokens(&rt);
    let b = rt.manifest.model.batch;
    let steps = 8;

    // Reference: static TP4 for both stages.
    let run = |prefill_s: ShardPlan, decode_s: ShardPlan| -> Vec<Vec<usize>> {
        let mut exec = ModelExecutor::new(&rt).unwrap();
        let logits = exec.prefill(&tokens, &prefill_s).unwrap();
        let mut out = vec![argmax_rows(&logits)];
        let mut last: Vec<i32> = out[0].iter().map(|&t| t as i32).collect();
        for _ in 0..steps {
            let logits = exec.decode_step(&last, &decode_s).unwrap();
            let next = argmax_rows(&logits);
            last = next.iter().map(|&t| t as i32).collect();
            out.push(next);
        }
        out
    };

    let tp = run(ShardPlan::tp(4), ShardPlan::tp(4));
    // HAP-style: EP4 experts for prefill, transition to TP4 for decode
    // (attention stays TP4 — pinned by the KV cache).
    let hap = run(plan(4, 1, 4), plan(4, 4, 1));
    assert_eq!(tp, hap, "dynamic parallelism transition changed generated tokens");
    assert_eq!(tp.len(), steps + 1);
    assert_eq!(tp[0].len(), b);
}

#[test]
fn decode_positions_advance_and_cache_limits_enforced() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(dir).expect("load artifacts");
    let tokens = test_tokens(&rt);
    let mut exec = ModelExecutor::new(&rt).unwrap();
    let s = ShardPlan::tp(2);
    exec.prefill(&tokens, &s).unwrap();
    assert_eq!(exec.pos, rt.manifest.model.prefill_len);
    let last = vec![1i32; rt.manifest.model.batch];
    exec.decode_step(&last, &s).unwrap();
    assert_eq!(exec.pos, rt.manifest.model.prefill_len + 1);
    // Attention strategy is pinned within a batch.
    let other = ShardPlan::tp(4);
    assert!(exec.decode_step(&last, &other).is_err());
}

#[test]
fn malformed_grids_rejected() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = PjrtRuntime::load(dir).expect("load artifacts");
    let tokens = test_tokens(&rt);
    let mut exec = ModelExecutor::new(&rt).unwrap();
    // Attention spans 8 devices but experts span 1: not a uniform grid.
    let bad = ShardPlan::new(AttnStrategy::new(8, 1), ExpertStrategy::new(1, 1));
    assert!(exec.prefill(&tokens, &bad).is_err());
    // Attention spans 2, experts span 4: mismatched device counts.
    let bad2 = ShardPlan::new(AttnStrategy::new(2, 1), ExpertStrategy::new(2, 2));
    assert!(exec.prefill(&tokens, &bad2).is_err());
    // Hybrid EP2×TP2 with matching device counts is a VALID grid now
    // (the old executor rejected it): validate accepts it.
    let hybrid = ShardPlan::new(AttnStrategy::new(4, 1), ExpertStrategy::new(2, 2));
    assert!(exec.validate(&hybrid).is_ok());
}
