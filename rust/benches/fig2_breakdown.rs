//! Paper Fig 2: per-layer latency breakdown of Mixtral-8x7B inference
//! under TP vs EP on 4×A6000 (PCIe), sequence length 2K, for both the
//! prefill and decoding stages.
//!
//! Shape to hold: prefill TP comm ≫ EP comm (TP loses on PCIe);
//! decode EP expert compute > TP expert compute (load imbalance).

mod common;

use hap::benchkit::{banner, write_results, Table};
use hap::config::{MoEModelConfig, NodeConfig, Scenario};
use hap::engine::Engine;
use hap::strategy::{AttnStrategy, ExpertStrategy};
use hap::util::json::Json;

fn main() {
    banner("fig2", "per-layer latency breakdown, Mixtral-8x7B, 4xA6000, seq 2K");
    let model = MoEModelConfig::mixtral_8x7b();
    let node = NodeConfig::a6000x(4);
    let sc = Scenario::new("fig2", 2048, 64, 16);
    let engine = Engine::new(&model, &node);

    // EP deployment = DP attention + EP experts (DeepSpeed-MoE).
    let tp = engine.run_static(&AttnStrategy::new(4, 1), &ExpertStrategy::new(4, 1), &sc, 1);
    let ep = engine.run_static(&AttnStrategy::new(1, 4), &ExpertStrategy::new(1, 4), &sc, 1);

    let nl = model.layers as f64;
    let mut t = Table::new(&["stage", "strategy", "attn (ms)", "expert (ms)", "comm (ms)"]);
    let mut json = Vec::new();
    for (stage, strat, b) in [
        ("prefill", "TP", &tp.prefill),
        ("prefill", "EP", &ep.prefill),
        ("decode", "TP", &tp.decode),
        ("decode", "EP", &ep.decode),
    ] {
        let steps = if stage == "decode" { sc.generate as f64 } else { 1.0 };
        let (a, e, c) = (b.attn / nl / steps, b.expert / nl / steps, b.comm / nl / steps);
        t.row(&[
            stage.into(),
            strat.into(),
            format!("{:.3}", a * 1e3),
            format!("{:.3}", e * 1e3),
            format!("{:.3}", c * 1e3),
        ]);
        json.push(Json::obj(vec![
            ("stage", stage.into()),
            ("strategy", strat.into()),
            ("attn_ms", (a * 1e3).into()),
            ("expert_ms", (e * 1e3).into()),
            ("comm_ms", (c * 1e3).into()),
        ]));
    }
    t.print();

    let pre_ratio = tp.prefill.comm / ep.prefill.comm;
    let dec_ratio = ep.decode.expert / tp.decode.expert;
    println!("\nprefill comm TP/EP = {pre_ratio:.2} (paper: TP ≫ EP on PCIe)");
    println!("decode expert EP/TP = {dec_ratio:.2} (paper: EP > TP from load imbalance)");
    assert!(pre_ratio > 1.5, "fig2 prefill shape lost");
    assert!(dec_ratio > 1.1, "fig2 decode shape lost");
    write_results("fig2", &Json::obj(vec![
        ("rows", Json::Arr(json)),
        ("prefill_comm_tp_over_ep", pre_ratio.into()),
        ("decode_expert_ep_over_tp", dec_ratio.into()),
    ]));
    println!("fig2 OK");
}
