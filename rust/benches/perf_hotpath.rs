//! §Perf hot-path benchmarks — the before/after measurements recorded
//! in BENCH_perf_hotpath.json at the repo root (and under
//! target/bench_results/). Covers each layer's L3-visible hot path:
//!
//!  - planner: full plan() — measured BOTH ways: the pre-change
//!    reference path (serial scalar cost tables, no memo, reference
//!    ILP solver) and the batched/parallel production path. The
//!    acceptance bar is a ≥3x median speedup on this row.
//!  - cost tables: scalar reference vs vectorized build
//!  - latency model: scalar layer_latency vs layer_latency_batch
//!  - forest: per-row predict vs SoA predict_batch throughput
//!  - ILP: reference vs flattened-tableau solver on the 8-GPU problem
//!  - engine: one simulated full run
//!  - quant: INT4 quantize/dequant throughput (transition path)
//!  - serving (if artifacts exist): PJRT decode-step wall time.

mod common;

use hap::benchkit::{banner, bench, write_results, Table};
use hap::config::{GpuSpec, MoEModelConfig, NodeConfig, Scenario};
use hap::engine::Engine;
use hap::planner::{HapPlanner, PLANNER_SEED};
use hap::quant::{self, Scheme};
use hap::sim::flops::Stage;
use hap::sim::forest::{ForestParams, RandomForest};
use hap::sim::{LatencyModel, LayerQuery};
use hap::strategy::{AttnStrategy, ExpertStrategy};
use hap::util::json::Json;
use hap::util::rng::Rng;
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    banner("perf", "hot-path timings");
    let mut t = Table::new(&["path", "median", "p95", "iters"]);
    let mut json = Vec::new();
    let mut record = |name: &str, timing: hap::benchkit::Timing| {
        t.row(&[
            name.into(),
            hap::util::fmt_secs(timing.median),
            hap::util::fmt_secs(timing.p95),
            format!("{}", timing.iters),
        ]);
        json.push(Json::obj(vec![
            ("path", name.into()),
            ("median_s", timing.median.into()),
            ("p95_s", timing.p95.into()),
        ]));
        timing
    };

    let model = MoEModelConfig::mixtral_8x7b();
    let node = NodeConfig::a100x(8);
    let sc = Scenario::long_extended();

    // Latency-model training (planner construction cost; amortized away
    // by the per-platform model cache in real use).
    let train = record(
        "latency-model train",
        bench("train", 1, 0.5, || {
            let lm = LatencyModel::train(&GpuSpec::a100(), 1);
            std::hint::black_box(lm.gpu.peak_flops);
        }),
    );

    // --- Planner full plan(): pre-change reference vs production.
    // The reference planner gets its own model with the scalar-path
    // memo disabled, so it reproduces the original per-entry forest
    // walks exactly.
    let mut lm_base = LatencyModel::train(&GpuSpec::a100(), PLANNER_SEED);
    lm_base.set_memo_enabled(false);
    let planner_base = HapPlanner::with_latency(&model, &node, Arc::new(lm_base));
    let plan_before = record(
        "planner full plan() [pre-change reference]",
        bench("plan-ref", 1, 0.6, || {
            let p = planner_base.plan_reference(&sc).unwrap();
            std::hint::black_box(p.predicted_total);
        }),
    );

    let planner = HapPlanner::new(&model, &node);
    let plan_t = record(
        "planner full plan()",
        bench("plan", 1, 0.6, || {
            let p = planner.plan(&sc, sc.generate).unwrap();
            std::hint::black_box(p.predicted_total);
        }),
    );
    let plan_speedup = plan_before.median / plan_t.median;
    println!("planner full plan(): {plan_speedup:.2}x vs pre-change reference");

    // --- Cost tables alone (the simulation hot path, no ILP).
    let space = planner.search_space(&sc);
    let tables_before = record(
        "cost_tables [scalar reference]",
        bench("tables-ref", 1, 0.4, || {
            let tb = planner_base.cost_tables_scalar(&space, &sc);
            std::hint::black_box(tb.attn_prefill[0]);
        }),
    );
    let tables_after = record(
        "cost_tables (batched+parallel)",
        bench("tables", 2, 0.4, || {
            let tb = planner.cost_tables(&space, &sc);
            std::hint::black_box(tb.attn_prefill[0]);
        }),
    );
    println!(
        "cost_tables: {:.2}x vs scalar reference",
        tables_before.median / tables_after.median
    );

    // --- Single latency query (planner inner loop) + batched form.
    let lm = LatencyModel::cached(&GpuSpec::a100(), 1);
    record(
        "layer_latency query",
        bench("layer", 10, 0.2, || {
            let l = lm.layer_latency(
                &model,
                &AttnStrategy::new(8, 1),
                &ExpertStrategy::new(1, 8),
                Stage::Prefill,
                16,
                4096,
            );
            std::hint::black_box(l.total());
        }),
    );
    let queries: Vec<LayerQuery> = (0..64)
        .map(|i| LayerQuery {
            attn: AttnStrategy::new(8, 1),
            expert: ExpertStrategy::new(1, 8),
            stage: if i % 2 == 0 { Stage::Prefill } else { Stage::Decode },
            batch: 16,
            seq: 1024 + 32 * i,
        })
        .collect();
    record(
        "layer_latency_batch (64 queries)",
        bench("layer-batch", 3, 0.2, || {
            let ls = lm.layer_latency_batch(&model, &queries);
            std::hint::black_box(ls.len());
        }),
    );

    // --- Forest predict throughput: per-row vs SoA batch.
    let (fxs, fys) = {
        let mut rng = Rng::new(11);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for _ in 0..900 {
            let row: Vec<f64> = (0..5).map(|_| rng.range_f64(-4.0, 4.0)).collect();
            ys.push(row.iter().sum::<f64>().sin());
            xs.push(row);
        }
        (xs, ys)
    };
    let forest = RandomForest::fit(
        &fxs,
        &fys,
        &ForestParams { n_trees: 24, max_depth: 12, min_split: 3, ..Default::default() },
    );
    let probe: Vec<Vec<f64>> = {
        let mut rng = Rng::new(13);
        (0..1000).map(|_| (0..5).map(|_| rng.range_f64(-4.0, 4.0)).collect()).collect()
    };
    record(
        "forest predict x1k (per-row)",
        bench("forest-scalar", 2, 0.2, || {
            let s: f64 = probe.iter().map(|x| forest.predict(x)).sum();
            std::hint::black_box(s);
        }),
    );
    record(
        "forest predict_batch x1k (SoA)",
        bench("forest-batch", 2, 0.2, || {
            let out = forest.predict_batch(&probe);
            std::hint::black_box(out.len());
        }),
    );

    // --- Forest traversal order at planner batch sizes: tree-major
    // (finish each tree over all rows) vs levelized BFS (advance every
    // in-flight row one level per pass). Same adds in the same order,
    // so the winner is chosen on time alone, bit-identity asserted.
    let tm_pred = forest.predict_batch_tree_major(&probe);
    let lv_pred = forest.predict_batch_levelized(&probe);
    assert_eq!(
        tm_pred.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        lv_pred.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "levelized forest traversal diverged from tree-major"
    );
    let forest_tm = record(
        "forest predict_batch x1k (tree-major)",
        bench("forest-tree-major", 2, 0.2, || {
            let out = forest.predict_batch_tree_major(&probe);
            std::hint::black_box(out.len());
        }),
    );
    let forest_lv = record(
        "forest predict_batch x1k (levelized BFS)",
        bench("forest-levelized", 2, 0.2, || {
            let out = forest.predict_batch_levelized(&probe);
            std::hint::black_box(out.len());
        }),
    );
    let forest_winner =
        if forest_lv.median <= forest_tm.median { "levelized" } else { "tree-major" };
    println!(
        "forest traversal: {forest_winner} wins ({:.2}x tree-major/levelized)",
        forest_tm.median / forest_lv.median
    );

    // --- Engine: full static run (32-layer model, prefill + decode).
    let engine = Engine::new(&model, &node);
    record(
        "engine full run",
        bench("engine", 1, 0.5, || {
            let r = engine.run_static(
                &AttnStrategy::new(8, 1),
                &ExpertStrategy::new(8, 1),
                &sc,
                1,
            );
            std::hint::black_box(r.total());
        }),
    );

    // --- ILP solve: reference vs flattened-tableau solver.
    let tables = planner.cost_tables(&space, &sc);
    let (problem, _) = planner.formulate(&space, &tables, &sc);
    let ilp_before = record(
        "ilp solve (8-gpu) [reference]",
        bench("ilp-ref", 1, 0.2, || {
            std::hint::black_box(hap::ilp::solve_reference(&problem).optimal().map(|(_, o)| o));
        }),
    );
    let ilp_after = record(
        "ilp solve (8-gpu)",
        bench("ilp", 2, 0.2, || {
            std::hint::black_box(hap::ilp::solve(&problem).optimal().map(|(_, o)| o));
        }),
    );
    println!("ilp solve: {:.2}x vs reference", ilp_before.median / ilp_after.median);

    // --- Quant hot path (16 MB panel).
    let mut rng = Rng::new(1);
    let data = rng.normal_vec_f32(4 * 1024 * 1024, 0.02);
    let qt = bench("quant", 1, 0.4, || {
        let q = quant::quantize(&data, 2048, 2048, Scheme::PerGroup { group_size: 128 });
        std::hint::black_box(q.packed.len());
    });
    println!(
        "quant throughput: {:.2} GB/s",
        (data.len() * 4) as f64 / qt.median / 1e9
    );
    record("int4 quantize 16MB", qt);
    let q = quant::quantize(&data, 2048, 2048, Scheme::PerGroup { group_size: 128 });
    let dq = bench("dequant", 1, 0.4, || {
        std::hint::black_box(quant::dequantize(&q).len());
    });
    println!(
        "dequant throughput: {:.2} GB/s (output)",
        (data.len() * 4) as f64 / dq.median / 1e9
    );
    record("int4 dequantize 16MB", dq);

    // --- PJRT serving hot path (needs artifacts).
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = hap::runtime::PjrtRuntime::load(dir)?;
        let m = rt.manifest.model.clone();
        let tokens: Vec<i32> =
            (0..m.batch * m.prefill_len).map(|i| ((i * 13 + 5) % m.vocab) as i32).collect();
        let mut exec = hap::model::ModelExecutor::new(&rt)?;
        let strat = hap::model::ShardPlan::tp(4);
        exec.prefill(&tokens, &strat)?;
        let last = vec![1i32; m.batch];
        record(
            "pjrt decode step (tp4)",
            bench("decode", 2, 1.0, || {
                // Reset position to avoid cache exhaustion during reps.
                if exec.pos >= m.max_len - 1 {
                    exec.prefill(&tokens, &strat).unwrap();
                }
                let l = exec.decode_step(&last, &strat).unwrap();
                std::hint::black_box(l.data[0]);
            }),
        );
        let mut exec1 = hap::model::ModelExecutor::new(&rt)?;
        let strat1 = hap::model::ShardPlan::tp(1);
        exec1.prefill(&tokens, &strat1)?;
        record(
            "pjrt decode step (tp1)",
            bench("decode1", 2, 1.0, || {
                if exec1.pos >= m.max_len - 1 {
                    exec1.prefill(&tokens, &strat1).unwrap();
                }
                let l = exec1.decode_step(&last, &strat1).unwrap();
                std::hint::black_box(l.data[0]);
            }),
        );
    } else {
        println!("(artifacts/ not built — skipping PJRT hot path)");
    }

    t.print();
    let summary = Json::obj(vec![
        ("bench", "perf_hotpath".into()),
        ("profile", "release".into()),
        (
            "planner_full_plan",
            Json::obj(vec![
                ("before_median_s", plan_before.median.into()),
                ("after_median_s", plan_t.median.into()),
                ("speedup", plan_speedup.into()),
            ]),
        ),
        (
            "cost_tables",
            Json::obj(vec![
                ("before_median_s", tables_before.median.into()),
                ("after_median_s", tables_after.median.into()),
                ("speedup", (tables_before.median / tables_after.median).into()),
            ]),
        ),
        (
            "ilp_solve",
            Json::obj(vec![
                ("before_median_s", ilp_before.median.into()),
                ("after_median_s", ilp_after.median.into()),
                ("speedup", (ilp_before.median / ilp_after.median).into()),
            ]),
        ),
        (
            "forest_traversal",
            Json::obj(vec![
                ("tree_major_median_s", forest_tm.median.into()),
                ("levelized_median_s", forest_lv.median.into()),
                ("speedup_tree_major_over_levelized", (forest_tm.median / forest_lv.median).into()),
                ("winner", forest_winner.into()),
                ("probe_rows", probe.len().into()),
            ]),
        ),
        ("rows", Json::Arr(json)),
    ]);
    write_results("perf_hotpath", &summary);
    // Track the perf trajectory across PRs at the repo root.
    let root_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_perf_hotpath.json");
    if let Err(e) = std::fs::write(&root_path, summary.to_string_pretty()) {
        eprintln!("could not write {}: {e}", root_path.display());
    } else {
        println!("wrote {}", root_path.display());
    }

    // Perf targets: DESIGN.md §7 plan budget + this PR's acceptance bar.
    assert!(plan_t.median < 0.5, "plan too slow: {:.3}s", plan_t.median);
    assert!(
        plan_speedup >= 3.0,
        "planner full plan() speedup {plan_speedup:.2}x below the 3x acceptance bar"
    );
    let _ = train;
    println!("perf_hotpath OK");
    Ok(())
}
