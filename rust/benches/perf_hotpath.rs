//! §Perf hot-path benchmarks — the before/after measurements recorded
//! in EXPERIMENTS.md §Perf. Covers each layer's L3-visible hot path:
//!
//!  - planner: full plan() (target < 50 ms) and its pieces
//!  - latency model: single layer_latency query (planner inner loop)
//!  - engine: one simulated layer step
//!  - ILP: solve on the 8-GPU formulation
//!  - quant: INT4 quantize/dequant throughput (transition path)
//!  - forest: regressor predict throughput
//!  - serving (if artifacts exist): PJRT decode-step wall time and
//!    serving-loop overhead on top of raw execute.

mod common;

use hap::benchkit::{banner, bench, write_results, Table};
use hap::config::{GpuSpec, MoEModelConfig, NodeConfig, Scenario};
use hap::engine::Engine;
use hap::planner::HapPlanner;
use hap::quant::{self, Scheme};
use hap::sim::flops::Stage;
use hap::sim::LatencyModel;
use hap::strategy::{AttnStrategy, ExpertStrategy};
use hap::util::json::Json;
use hap::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    banner("perf", "hot-path timings");
    let mut t = Table::new(&["path", "median", "p95", "iters"]);
    let mut json = Vec::new();
    let mut record = |name: &str, timing: hap::benchkit::Timing| {
        t.row(&[
            name.into(),
            hap::util::fmt_secs(timing.median),
            hap::util::fmt_secs(timing.p95),
            format!("{}", timing.iters),
        ]);
        json.push(Json::obj(vec![
            ("path", name.into()),
            ("median_s", timing.median.into()),
            ("p95_s", timing.p95.into()),
        ]));
        timing
    };

    let model = MoEModelConfig::mixtral_8x7b();
    let node = NodeConfig::a100x(8);
    let sc = Scenario::long_extended();

    // Latency-model training (planner construction cost).
    let train = record(
        "latency-model train",
        bench("train", 1, 0.5, || {
            let lm = LatencyModel::train(&GpuSpec::a100(), 1);
            std::hint::black_box(lm.gpu.peak_flops);
        }),
    );

    // Planner full plan.
    let planner = HapPlanner::new(&model, &node);
    let plan_t = record(
        "planner full plan()",
        bench("plan", 1, 0.5, || {
            let p = planner.plan(&sc, sc.generate).unwrap();
            std::hint::black_box(p.predicted_total);
        }),
    );

    // Single latency query (planner inner loop).
    let lm = LatencyModel::train(&GpuSpec::a100(), 1);
    record(
        "layer_latency query",
        bench("layer", 10, 0.2, || {
            let l = lm.layer_latency(
                &model,
                &AttnStrategy::new(8, 1),
                &ExpertStrategy::new(1, 8),
                Stage::Prefill,
                16,
                4096,
            );
            std::hint::black_box(l.total());
        }),
    );

    // Engine: full static run (32-layer model, prefill + decode).
    let engine = Engine::new(&model, &node);
    record(
        "engine full run",
        bench("engine", 1, 0.5, || {
            let r = engine.run_static(
                &AttnStrategy::new(8, 1),
                &ExpertStrategy::new(8, 1),
                &sc,
                1,
            );
            std::hint::black_box(r.total());
        }),
    );

    // ILP solve.
    let space = planner.search_space(&sc);
    let tables = planner.cost_tables(&space, &sc);
    let (problem, _) = planner.formulate(&space, &tables, &sc);
    record(
        "ilp solve (8-gpu)",
        bench("ilp", 2, 0.2, || {
            std::hint::black_box(hap::ilp::solve(&problem).optimal().map(|(_, o)| o));
        }),
    );

    // Quant hot path (16 MB panel).
    let mut rng = Rng::new(1);
    let data = rng.normal_vec_f32(4 * 1024 * 1024, 0.02);
    let qt = bench("quant", 1, 0.4, || {
        let q = quant::quantize(&data, 2048, 2048, Scheme::PerGroup { group_size: 128 });
        std::hint::black_box(q.packed.len());
    });
    println!(
        "quant throughput: {:.2} GB/s",
        (data.len() * 4) as f64 / qt.median / 1e9
    );
    record("int4 quantize 16MB", qt);
    let q = quant::quantize(&data, 2048, 2048, Scheme::PerGroup { group_size: 128 });
    let dq = bench("dequant", 1, 0.4, || {
        std::hint::black_box(quant::dequantize(&q).len());
    });
    println!(
        "dequant throughput: {:.2} GB/s (output)",
        (data.len() * 4) as f64 / dq.median / 1e9
    );
    record("int4 dequantize 16MB", dq);

    // PJRT serving hot path (needs artifacts).
    let dir = std::path::Path::new("artifacts");
    if dir.join("manifest.json").exists() {
        let rt = hap::runtime::PjrtRuntime::load(dir)?;
        let m = rt.manifest.model.clone();
        let tokens: Vec<i32> =
            (0..m.batch * m.prefill_len).map(|i| ((i * 13 + 5) % m.vocab) as i32).collect();
        let mut exec = hap::model::ModelExecutor::new(&rt)?;
        let strat = hap::model::StageStrategy::tp(4);
        exec.prefill(&tokens, &strat)?;
        let last = vec![1i32; m.batch];
        record(
            "pjrt decode step (tp4)",
            bench("decode", 2, 1.0, || {
                // Reset position to avoid cache exhaustion during reps.
                if exec.pos >= m.max_len - 1 {
                    exec.prefill(&tokens, &strat).unwrap();
                }
                let l = exec.decode_step(&last, &strat).unwrap();
                std::hint::black_box(l.data[0]);
            }),
        );
        let mut exec1 = hap::model::ModelExecutor::new(&rt)?;
        let strat1 = hap::model::StageStrategy::tp(1);
        exec1.prefill(&tokens, &strat1)?;
        record(
            "pjrt decode step (tp1)",
            bench("decode1", 2, 1.0, || {
                if exec1.pos >= m.max_len - 1 {
                    exec1.prefill(&tokens, &strat1).unwrap();
                }
                let l = exec1.decode_step(&last, &strat1).unwrap();
                std::hint::black_box(l.data[0]);
            }),
        );
    } else {
        println!("(artifacts/ not built — skipping PJRT hot path)");
    }

    t.print();
    write_results("perf_hotpath", &Json::obj(vec![("rows", Json::Arr(json))]));
    // Perf targets from DESIGN.md §7.
    assert!(plan_t.median < 0.5, "plan too slow: {:.3}s", plan_t.median);
    let _ = train;
    println!("perf_hotpath OK");
    Ok(())
}
