//! Ablation studies of HAP's design choices (DESIGN.md §4 "ablation
//! benches"):
//!
//!  A1 — dynamic parallelism transition: HAP with per-stage expert
//!       strategies + transition vs HAP restricted to one static expert
//!       strategy (still searched). Quantifies what eq. 6 buys.
//!  A2 — transition mechanism: INT4 backup vs reshard-only (force
//!       C_ij = T_reshard). Quantifies the CPU-backup pipeline's value.
//!  A3 — η/ρ regressors vs naive roofline (η = ρ = 1): how much
//!       decision quality the learned correction factors add, measured
//!       as regret of the naive planner's choice under the engine.
//!  A4 — EP load-imbalance modeling: planner with imbalance = 1
//!       (ignored) vs modeled. Shows why decode avoids EP.

mod common;

use hap::benchkit::{banner, write_results, Table};
use hap::config::{MoEModelConfig, NodeConfig, Scenario};
use hap::engine::Engine;
use hap::planner::HapPlanner;
use hap::strategy::{AttnStrategy, ExpertStrategy};
use hap::util::json::Json;

fn main() -> anyhow::Result<()> {
    let model = MoEModelConfig::mixtral_8x7b();
    let node = NodeConfig::a6000x(4);
    let planner = HapPlanner::new(&model, &node);
    let engine = Engine::new(&model, &node);
    let mut json = Vec::new();

    // ---------------- A1: value of per-stage strategies + transition.
    banner("ablation-A1", "per-stage strategies + transition vs single static strategy");
    let mut t = Table::new(&["scenario", "HAP full (s)", "HAP static-only (s)", "benefit"]);
    for sc in [Scenario::long_constrained(), Scenario::long_extended(), Scenario::fig8_v100()] {
        let full = planner.plan(&sc, sc.generate)?;
        let full_s = engine.run_plan(&full, &sc, 1).total();
        // Static-only: best single (attn, expert) pair by brute force
        // over the same cost tables (no transition allowed).
        let space = planner.search_space(&sc);
        let mut best: Option<f64> = None;
        for a in &space.attn {
            for e in &space.expert {
                let pred = planner.predict_fixed(&sc, a, e);
                if best.map_or(true, |b| pred < b) {
                    best = Some(pred);
                }
            }
        }
        // Measure the argmin on the engine.
        let mut best_measured = f64::INFINITY;
        for a in &space.attn {
            for e in &space.expert {
                let m = engine.run_static(a, e, &sc, 1).total();
                if planner.predict_fixed(&sc, a, e)
                    <= best.unwrap() * (1.0 + 1e-9)
                {
                    best_measured = best_measured.min(m);
                }
            }
        }
        t.row(&[
            sc.name.clone(),
            format!("{full_s:.3}"),
            format!("{best_measured:.3}"),
            format!("{:.2}x", best_measured / full_s),
        ]);
        json.push(Json::obj(vec![
            ("ablation", "A1".into()),
            ("scenario", sc.name.as_str().into()),
            ("hap_full_s", full_s.into()),
            ("hap_static_s", best_measured.into()),
        ]));
        // The full planner can never be worse than its static subset
        // by more than the transition mispricing tolerance.
        assert!(full_s <= best_measured * 1.05, "{}: transition hurt", sc.name);
    }
    t.print();

    // ---------------- A3: learned η/ρ vs naive roofline planner.
    banner("ablation-A3", "learned η/ρ correction vs naive peak-FLOPs/bandwidth model");
    // Naive decision: rank strategies by F/peak + V/BW (η=ρ=1). Done by
    // re-deriving costs with a flat latency model.
    let mut t3 = Table::new(&["scenario", "naive pick regret", "HAP pick regret"]);
    for sc in [Scenario::long_constrained(), Scenario::short_extended()] {
        let space = planner.search_space(&sc);
        // Engine-measured optimum over static pairs (reference).
        let mut measured: Vec<(String, f64)> = Vec::new();
        for a in &space.attn {
            for e in &space.expert {
                measured.push((format!("{a}/{e}"), engine.run_static(a, e, &sc, 1).total()));
            }
        }
        let opt = measured.iter().map(|(_, m)| *m).fold(f64::INFINITY, f64::min);
        // Naive pick: flops/peak + bytes/link_bw, no correction.
        let gpu = &node.gpu;
        let naive_cost = |a: &AttnStrategy, e: &ExpertStrategy| -> f64 {
            use hap::sim::comm::{layer_comm_bytes, layer_comm_events};
            use hap::sim::flops::{attention_cost, expert_cost, Stage};
            let pre_a = attention_cost(&model, a, Stage::Prefill, sc.batch, sc.context);
            let pre_e = expert_cost(&model, e, Stage::Prefill, sc.batch, sc.context, 1.0);
            let pre_c = layer_comm_bytes(&layer_comm_events(
                &model, a, e, Stage::Prefill, sc.batch, sc.context,
            ));
            let dec_ctx = sc.context + sc.generate / 2;
            let dec_a = attention_cost(&model, a, Stage::Decode, sc.batch, dec_ctx);
            let dec_e = expert_cost(&model, e, Stage::Decode, sc.batch, dec_ctx, 1.0);
            let dec_c = layer_comm_bytes(&layer_comm_events(
                &model, a, e, Stage::Decode, sc.batch, dec_ctx,
            ));
            let nl = model.layers as f64;
            nl * ((pre_a.flops + pre_e.flops) / gpu.peak_flops + pre_c / gpu.link_bw)
                + sc.generate as f64
                    * nl
                    * ((dec_a.flops + dec_e.flops) / gpu.peak_flops + dec_c / gpu.link_bw)
        };
        let mut naive_best: Option<(f64, f64)> = None; // (cost, measured)
        for (i, a) in space.attn.iter().enumerate() {
            for (j, e) in space.expert.iter().enumerate() {
                let c = naive_cost(a, e);
                let m = measured[i * space.expert.len() + j].1;
                if naive_best.map_or(true, |(bc, _)| c < bc) {
                    naive_best = Some((c, m));
                }
            }
        }
        let naive_regret = naive_best.unwrap().1 / opt;
        let hap_plan = planner.plan(&sc, sc.generate)?;
        let hap_measured = engine.run_plan(&hap_plan, &sc, 1).total();
        let hap_regret = hap_measured / opt;
        t3.row(&[
            sc.name.clone(),
            format!("{:.3}x", naive_regret),
            format!("{:.3}x", hap_regret),
        ]);
        json.push(Json::obj(vec![
            ("ablation", "A3".into()),
            ("scenario", sc.name.as_str().into()),
            ("naive_regret", naive_regret.into()),
            ("hap_regret", hap_regret.into()),
        ]));
        assert!(
            hap_regret <= naive_regret + 0.02,
            "{}: learned model should not be worse than naive",
            sc.name
        );
    }
    t3.print();

    // ---------------- A4: imbalance modeling ablation.
    banner("ablation-A4", "EP decode penalty with vs without imbalance modeling");
    let sc = Scenario::new("a4", 2048, 256, 16);
    let ep = ExpertStrategy::new(1, 4);
    let a = AttnStrategy::new(1, 4);
    let with_imb = planner.predict_fixed(&sc, &a, &ep);
    let measured = engine.run_static(&a, &ep, &sc, 1).total();
    println!(
        "EP4 decode-heavy prediction {:.3}s vs engine-measured {:.3}s (ratio {:.2})",
        with_imb,
        measured,
        with_imb / measured
    );
    // The imbalance-aware prediction must land within 35% of measured.
    assert!((with_imb / measured - 1.0).abs() < 0.35, "imbalance-aware prediction off");
    json.push(Json::obj(vec![
        ("ablation", "A4".into()),
        ("predicted_s", with_imb.into()),
        ("measured_s", measured.into()),
    ]));

    write_results("ablations", &Json::obj(vec![("rows", Json::Arr(json))]));
    println!("ablations OK");
    Ok(())
}
