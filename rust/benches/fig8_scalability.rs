//! Paper Fig 8: (a) 8×A100, 2048-ctx/128-gen; (b) 8×V100,
//! 2048-ctx/64-gen; (c) TP vs EP vs HAP prefill/decode latency split
//! on 4×A6000 — the dynamic-transition money shot: HAP prefill ≈ EP
//! prefill, HAP decode ≈ TP decode.

mod common;

use common::{report, speedup_row, BATCHES};
use hap::benchkit::{banner, write_results, Table};
use hap::config::{MoEModelConfig, NodeConfig, Scenario};
use hap::engine::Engine;
use hap::planner::{HapPlanner, PLANNER_SEED};
use hap::sim::LatencyModel;
use hap::strategy::{AttnStrategy, ExpertStrategy};
use hap::util::json::Json;

fn main() -> anyhow::Result<()> {
    let model = MoEModelConfig::mixtral_8x7b();

    // (a) + (b): 8-GPU scaling. Warm the per-platform model cache once
    // up front; every speedup_row's planner then reuses the same
    // trained forests across the batch sweep instead of retraining.
    for (node, sc) in [
        (NodeConfig::a100x(8), Scenario::fig8_a100()),
        (NodeConfig::v100x(8), Scenario::fig8_v100()),
    ] {
        let _ = LatencyModel::cached(&node.gpu, PLANNER_SEED);
        let mut rows = Vec::new();
        for b in BATCHES {
            rows.push(speedup_row(&model, &node, &sc.with_batch(b), 1)?);
        }
        report(
            &format!("fig8_{}", node.label()),
            &format!("Mixtral-8x7B {} on {}", sc.name, node.label()),
            &rows,
        );
        for r in &rows {
            assert!(r.speedup > 0.97, "HAP lost on {}: {}", node.label(), r.speedup);
        }
    }

    // (c): prefill/decode split, TP vs EP vs HAP on 4×A6000.
    banner("fig8c", "prefill/decode latency: TP vs EP vs HAP (4xA6000)");
    let node = NodeConfig::a6000x(4);
    let sc = Scenario::new("fig8c", 2048, 64, 16);
    let engine = Engine::new(&model, &node);
    let planner =
        HapPlanner::with_latency(&model, &node, LatencyModel::cached(&node.gpu, PLANNER_SEED));
    let plan = planner.plan(&sc, sc.generate)?;

    let tp = engine.run_static(&AttnStrategy::new(4, 1), &ExpertStrategy::new(4, 1), &sc, 1);
    let ep = engine.run_static(&AttnStrategy::new(1, 4), &ExpertStrategy::new(1, 4), &sc, 1);
    let hap = engine.run_plan(&plan, &sc, 1);

    let mut t = Table::new(&["config", "prefill (s)", "decode (s)", "transition (s)", "total (s)"]);
    for (name, r) in [("TP", &tp), ("EP", &ep), ("HAP", &hap)] {
        t.row(&[
            name.into(),
            format!("{:.3}", r.prefill.total()),
            format!("{:.3}", r.decode.total() - r.decode.transition),
            format!("{:.3}", r.decode.transition),
            format!("{:.3}", r.total()),
        ]);
    }
    t.print();
    println!("HAP plan: {}", plan.signature());

    // Shape assertions: EP prefill < TP prefill; EP decode > TP decode;
    // HAP ≤ best of both per stage (within tolerance + transition).
    assert!(ep.prefill.total() < tp.prefill.total(), "EP should win prefill");
    assert!(ep.decode.total() > tp.decode.total(), "TP should win decode");
    assert!(
        hap.prefill.total() < tp.prefill.total() * 1.02,
        "HAP prefill should track the better strategy"
    );
    assert!(
        hap.decode.total() - hap.decode.transition < ep.decode.total(),
        "HAP decode should beat EP decode"
    );
    write_results(
        "fig8c",
        &Json::obj(vec![
            ("tp_prefill", tp.prefill.total().into()),
            ("ep_prefill", ep.prefill.total().into()),
            ("hap_prefill", hap.prefill.total().into()),
            ("tp_decode", tp.decode.total().into()),
            ("ep_decode", ep.decode.total().into()),
            ("hap_decode", hap.decode.total().into()),
            ("hap_transition", hap.decode.transition.into()),
            ("plan", plan.signature().as_str().into()),
        ]),
    );
    println!("fig8 OK");
    Ok(())
}
