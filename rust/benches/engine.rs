//! §Grid-engine benchmark — BENCH_engine.json at the repo root.
//!
//! Measures the execution engine itself, artifact-free (host kernels on
//! seeded synthetic weights):
//!
//!  - parallel (scoped-thread per device) vs sequential shard
//!    execution: full prefill + short decode under the hybrid
//!    EP2×TP2 grid, with bit-identical outputs asserted;
//!  - per-batch weight-upload counts: the old per-batch-executor
//!    behavior (fresh executor every batch, as `serve_workload` did
//!    before the persistent engine) vs one long-lived executor;
//!  - measured resharding work of a plan switch;
//!  - blocked packed kernels vs the scalar reference path, per phase
//!    (prefill / decode steps), with bit-identical logits asserted and
//!    the combined step speedup gated at ≥ 2× (the CI bar);
//!  - end-to-end quantized serving (`--quant int8|int4`): tok/s,
//!    resident weight bytes, and greedy-token agreement vs f32.

use hap::benchkit::{banner, bench, write_results, Table};
use hap::model::{EngineMode, KernelMode, ModelExecutor, ShardPlan, WeightStore};
use hap::quant::QuantKind;
use hap::runtime::literal::argmax_rows;
use hap::runtime::TinyModelMeta;
use hap::serving::{serve_on, Request, ServeConfig};
use hap::strategy::{AttnStrategy, ExpertStrategy};
use hap::util::json::Json;
use std::time::Instant;

/// Bench model: bigger than the test meta so per-device compute
/// dominates thread-spawn overhead, smaller than TINY so the bench
/// stays in seconds.
fn bench_meta() -> TinyModelMeta {
    TinyModelMeta {
        batch: 4,
        prefill_len: 32,
        max_len: 64,
        hidden: 128,
        q_heads: 8,
        kv_heads: 4,
        head_dim: 16,
        num_experts: 8,
        top_k: 2,
        inter: 256,
        vocab: 256,
        layers: 2,
    }
}

fn tokens(m: &TinyModelMeta) -> Vec<i32> {
    (0..m.batch * m.prefill_len)
        .map(|i| ((i * 37 + 11) % m.vocab) as i32)
        .collect()
}

fn run_batch(exec: &mut ModelExecutor, toks: &[i32], plan: &ShardPlan, steps: usize) -> f32 {
    exec.begin_batch(plan, plan).unwrap();
    let logits = exec.prefill(toks, plan).unwrap();
    let mut last: Vec<i32> = hap::runtime::literal::argmax_rows(&logits)
        .iter()
        .map(|&t| t as i32)
        .collect();
    let mut sink = logits.data[0];
    for _ in 0..steps {
        let l = exec.decode_step(&last, plan).unwrap();
        last = hap::runtime::literal::argmax_rows(&l).iter().map(|&t| t as i32).collect();
        sink += l.data[0];
    }
    sink
}

/// Median prefill / decode-phase wall times over `rounds` batches on a
/// warm executor in the given kernel mode, plus the first round's full
/// logit bit pattern (prefill + every decode step) for identity checks.
fn phase_profile(
    mode: KernelMode,
    m: &TinyModelMeta,
    toks: &[i32],
    plan: &ShardPlan,
    steps: usize,
    rounds: usize,
) -> (f64, f64, Vec<u32>) {
    let mut exec = ModelExecutor::host(WeightStore::synthetic(m, 42));
    exec.set_kernel_mode(mode).unwrap();
    run_batch(&mut exec, toks, plan, steps); // warm resident shards
    let mut prefill_ts = Vec::with_capacity(rounds);
    let mut decode_ts = Vec::with_capacity(rounds);
    let mut sig = Vec::new();
    for r in 0..rounds {
        exec.begin_batch(plan, plan).unwrap();
        let t0 = Instant::now();
        let logits = exec.prefill(toks, plan).unwrap();
        prefill_ts.push(t0.elapsed().as_secs_f64());
        if r == 0 {
            sig.extend(logits.data.iter().map(|v| v.to_bits()));
        }
        let mut last: Vec<i32> = argmax_rows(&logits).iter().map(|&t| t as i32).collect();
        let t0 = Instant::now();
        for _ in 0..steps {
            let l = exec.decode_step(&last, plan).unwrap();
            last = argmax_rows(&l).iter().map(|&t| t as i32).collect();
            if r == 0 {
                sig.extend(l.data.iter().map(|v| v.to_bits()));
            }
        }
        decode_ts.push(t0.elapsed().as_secs_f64());
    }
    prefill_ts.sort_by(f64::total_cmp);
    decode_ts.sort_by(f64::total_cmp);
    (prefill_ts[rounds / 2], decode_ts[rounds / 2], sig)
}

/// Gang workload for the quantized-serving comparison: two full
/// batches of prefill-length prompts.
fn quant_workload(m: &TinyModelMeta) -> Vec<Request> {
    (0..2 * m.batch as u64)
        .map(|i| {
            let prompt: Vec<i32> = (0..m.prefill_len)
                .map(|t| ((i as usize * 31 + t * 13 + 5) % m.vocab) as i32)
                .collect();
            Request::new(i, prompt, 16)
        })
        .collect()
}

fn main() -> anyhow::Result<()> {
    banner("engine", "grid execution engine: parallel shards + persistent state");
    let m = bench_meta();
    let toks = tokens(&m);
    let hybrid = ShardPlan::new(AttnStrategy::new(4, 1), ExpertStrategy::new(2, 2));
    let tp = ShardPlan::tp(4);

    // --- Correctness gate: parallel ≡ sequential, bit for bit.
    let logits_of = |mode: EngineMode| {
        let mut exec = ModelExecutor::host_with_mode(WeightStore::synthetic(&m, 42), mode);
        exec.begin_batch(&hybrid, &hybrid).unwrap();
        exec.prefill(&toks, &hybrid).unwrap()
    };
    let par = logits_of(EngineMode::Parallel);
    let seq = logits_of(EngineMode::Sequential);
    assert_eq!(
        par.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        seq.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "parallel and sequential shard execution diverged"
    );
    println!("hybrid EP2xTP2 parallel == sequential (bit-identical)");

    // --- Parallel vs sequential wall time (persistent executors, so
    // only compute + collectives are measured, not shard slicing).
    let mut t = Table::new(&["path", "median", "p95", "iters"]);
    let mut exec_par =
        ModelExecutor::host_with_mode(WeightStore::synthetic(&m, 42), EngineMode::Parallel);
    run_batch(&mut exec_par, &toks, &hybrid, 2); // warm shards
    let par_t = bench("engine-parallel", 1, 1.0, || {
        std::hint::black_box(run_batch(&mut exec_par, &toks, &hybrid, 2));
    });
    let mut exec_seq =
        ModelExecutor::host_with_mode(WeightStore::synthetic(&m, 42), EngineMode::Sequential);
    run_batch(&mut exec_seq, &toks, &hybrid, 2);
    let seq_t = bench("engine-sequential", 1, 1.0, || {
        std::hint::black_box(run_batch(&mut exec_seq, &toks, &hybrid, 2));
    });
    let speedup = seq_t.median / par_t.median;
    for (name, timing) in [("parallel shards", &par_t), ("sequential shards", &seq_t)] {
        t.row(&[
            name.into(),
            hap::util::fmt_secs(timing.median),
            hap::util::fmt_secs(timing.p95),
            format!("{}", timing.iters),
        ]);
    }
    t.print();
    println!("parallel-vs-sequential shard execution: {speedup:.2}x");

    // --- Weight-upload amortization: fresh executor per batch (the
    // pre-refactor serve_workload behavior) vs one persistent executor.
    let batches = 4usize;
    let mut fresh_uploads = 0usize;
    for _ in 0..batches {
        let mut exec = ModelExecutor::host(WeightStore::synthetic(&m, 7));
        run_batch(&mut exec, &toks, &tp, 1);
        fresh_uploads += exec.stats().materializations;
    }
    let mut persistent = ModelExecutor::host(WeightStore::synthetic(&m, 7));
    for _ in 0..batches {
        run_batch(&mut persistent, &toks, &tp, 1);
    }
    let persistent_uploads = persistent.stats().materializations;
    assert_eq!(
        persistent_uploads * batches,
        fresh_uploads,
        "persistent executor should upload one batch's worth of shards once"
    );
    println!(
        "weight uploads over {batches} batches: fresh-per-batch {fresh_uploads} vs persistent {persistent_uploads}"
    );

    // --- Measured resharding work of one plan switch.
    let before = persistent.stats();
    run_batch(&mut persistent, &toks, &hybrid, 1);
    let after = persistent.stats();
    let switch_uploads = after.materializations - before.materializations;
    assert!(switch_uploads > 0, "plan switch moved no weights");
    assert_eq!(after.reshards, before.reshards + 1);
    println!(
        "plan switch TP4 -> EP2xTP2: {} shard uploads, {:.3} ms measured",
        switch_uploads,
        (after.reshard_seconds - before.reshard_seconds) * 1e3
    );

    // --- Blocked packed kernels vs the scalar reference path, per
    // phase, on a warm TP4 executor. Bit-identity first: the packed
    // layout must not change a single logit bit.
    let steps = 8usize;
    let (blk_p, blk_d, blk_sig) = phase_profile(KernelMode::Blocked, &m, &toks, &tp, steps, 5);
    let (ref_p, ref_d, ref_sig) = phase_profile(KernelMode::Reference, &m, &toks, &tp, steps, 5);
    assert_eq!(blk_sig, ref_sig, "blocked kernels changed engine logits");
    let prefill_speedup = ref_p / blk_p;
    let decode_speedup = ref_d / blk_d;
    let step_speedup = (ref_p + ref_d) / (blk_p + blk_d);
    println!(
        "blocked vs reference kernels (bit-identical logits): prefill {prefill_speedup:.2}x, \
         decode ({steps} steps) {decode_speedup:.2}x, combined {step_speedup:.2}x"
    );
    assert!(
        step_speedup >= 2.0,
        "blocked kernels must be >= 2x the scalar reference per step, got {step_speedup:.2}x"
    );

    // --- Quantized serving end to end: same workload under f32, int8,
    // int4 packed weights on the host backend.
    let mut quant_rows = Vec::new();
    let mut f32_tokens: Vec<Vec<i32>> = Vec::new();
    let mut f32_bytes = 0usize;
    let mut qt = Table::new(&["weights", "tok/s", "resident MiB", "agreement vs f32"]);
    for (label, quant) in
        [("f32", None), ("int8", Some(QuantKind::Int8)), ("int4", Some(QuantKind::Int4))]
    {
        let mut cfg = ServeConfig::tp(4);
        cfg.quant = quant;
        let mut exec = ModelExecutor::host(WeightStore::synthetic(&m, 42));
        let t0 = Instant::now();
        let report = serve_on(&mut exec, &cfg, quant_workload(&m))?;
        let secs = t0.elapsed().as_secs_f64();
        let mut responses = report.responses;
        responses.sort_by_key(|r| r.id);
        let generated: usize = responses.iter().map(|r| r.tokens.len()).sum();
        assert!(generated > 0, "{label} serving generated nothing");
        let tok_s = generated as f64 / secs;
        let bytes = exec.resident_weight_bytes();
        let agreement = if f32_tokens.is_empty() {
            f32_tokens = responses.iter().map(|r| r.tokens.clone()).collect();
            f32_bytes = bytes;
            1.0
        } else {
            assert!(bytes < f32_bytes, "{label} shards should be smaller than f32");
            let (mut same, mut total) = (0usize, 0usize);
            for (a, b) in f32_tokens.iter().zip(&responses) {
                total += a.len().max(b.tokens.len());
                same += a.iter().zip(&b.tokens).filter(|(x, y)| x == y).count();
            }
            same as f64 / total.max(1) as f64
        };
        qt.row(&[
            label.into(),
            format!("{tok_s:.0}"),
            format!("{:.2}", bytes as f64 / (1 << 20) as f64),
            format!("{agreement:.3}"),
        ]);
        quant_rows.push((
            label,
            Json::obj(vec![
                ("tok_s", tok_s.into()),
                ("generated_tokens", generated.into()),
                ("weight_bytes", bytes.into()),
                ("greedy_agreement_vs_f32", agreement.into()),
            ]),
        ));
    }
    qt.print();

    let summary = Json::obj(vec![
        ("bench", "engine".into()),
        ("profile", "release".into()),
        (
            "parallel_vs_sequential",
            Json::obj(vec![
                ("parallel_median_s", par_t.median.into()),
                ("sequential_median_s", seq_t.median.into()),
                ("speedup", speedup.into()),
                ("devices", 4usize.into()),
            ]),
        ),
        (
            "weight_uploads",
            Json::obj(vec![
                ("batches", batches.into()),
                ("fresh_per_batch_total", fresh_uploads.into()),
                ("persistent_total", persistent_uploads.into()),
                (
                    "amortization",
                    (fresh_uploads as f64 / persistent_uploads.max(1) as f64).into(),
                ),
            ]),
        ),
        (
            "plan_switch",
            Json::obj(vec![
                ("uploads", switch_uploads.into()),
                (
                    "measured_s",
                    (after.reshard_seconds - before.reshard_seconds).into(),
                ),
            ]),
        ),
        (
            "kernels",
            Json::obj(vec![
                ("blocked_prefill_s", blk_p.into()),
                ("blocked_decode_s", blk_d.into()),
                ("reference_prefill_s", ref_p.into()),
                ("reference_decode_s", ref_d.into()),
                ("prefill_speedup", prefill_speedup.into()),
                ("decode_speedup", decode_speedup.into()),
                ("step_speedup", step_speedup.into()),
                ("decode_steps", steps.into()),
                ("bit_identical", true.into()),
            ]),
        ),
        ("quant_serving", Json::obj(quant_rows)),
    ]);
    write_results("engine", &summary);
    let root_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_engine.json");
    if let Err(e) = std::fs::write(&root_path, summary.to_string_pretty()) {
        eprintln!("could not write {}: {e}", root_path.display());
    } else {
        println!("wrote {}", root_path.display());
    }
    println!("engine bench OK");
    Ok(())
}
