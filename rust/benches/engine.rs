//! §Grid-engine benchmark — BENCH_engine.json at the repo root.
//!
//! Measures the execution engine itself, artifact-free (host kernels on
//! seeded synthetic weights):
//!
//!  - parallel (scoped-thread per device) vs sequential shard
//!    execution: full prefill + short decode under the hybrid
//!    EP2×TP2 grid, with bit-identical outputs asserted;
//!  - per-batch weight-upload counts: the old per-batch-executor
//!    behavior (fresh executor every batch, as `serve_workload` did
//!    before the persistent engine) vs one long-lived executor;
//!  - measured resharding work of a plan switch.

use hap::benchkit::{banner, bench, write_results, Table};
use hap::model::{EngineMode, ModelExecutor, ShardPlan, WeightStore};
use hap::runtime::TinyModelMeta;
use hap::strategy::{AttnStrategy, ExpertStrategy};
use hap::util::json::Json;

/// Bench model: bigger than the test meta so per-device compute
/// dominates thread-spawn overhead, smaller than TINY so the bench
/// stays in seconds.
fn bench_meta() -> TinyModelMeta {
    TinyModelMeta {
        batch: 4,
        prefill_len: 32,
        max_len: 64,
        hidden: 128,
        q_heads: 8,
        kv_heads: 4,
        head_dim: 16,
        num_experts: 8,
        top_k: 2,
        inter: 256,
        vocab: 256,
        layers: 2,
    }
}

fn tokens(m: &TinyModelMeta) -> Vec<i32> {
    (0..m.batch * m.prefill_len)
        .map(|i| ((i * 37 + 11) % m.vocab) as i32)
        .collect()
}

fn run_batch(exec: &mut ModelExecutor, toks: &[i32], plan: &ShardPlan, steps: usize) -> f32 {
    exec.begin_batch(plan, plan).unwrap();
    let logits = exec.prefill(toks, plan).unwrap();
    let mut last: Vec<i32> = hap::runtime::literal::argmax_rows(&logits)
        .iter()
        .map(|&t| t as i32)
        .collect();
    let mut sink = logits.data[0];
    for _ in 0..steps {
        let l = exec.decode_step(&last, plan).unwrap();
        last = hap::runtime::literal::argmax_rows(&l).iter().map(|&t| t as i32).collect();
        sink += l.data[0];
    }
    sink
}

fn main() -> anyhow::Result<()> {
    banner("engine", "grid execution engine: parallel shards + persistent state");
    let m = bench_meta();
    let toks = tokens(&m);
    let hybrid = ShardPlan::new(AttnStrategy::new(4, 1), ExpertStrategy::new(2, 2));
    let tp = ShardPlan::tp(4);

    // --- Correctness gate: parallel ≡ sequential, bit for bit.
    let logits_of = |mode: EngineMode| {
        let mut exec = ModelExecutor::host_with_mode(WeightStore::synthetic(&m, 42), mode);
        exec.begin_batch(&hybrid, &hybrid).unwrap();
        exec.prefill(&toks, &hybrid).unwrap()
    };
    let par = logits_of(EngineMode::Parallel);
    let seq = logits_of(EngineMode::Sequential);
    assert_eq!(
        par.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        seq.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
        "parallel and sequential shard execution diverged"
    );
    println!("hybrid EP2xTP2 parallel == sequential (bit-identical)");

    // --- Parallel vs sequential wall time (persistent executors, so
    // only compute + collectives are measured, not shard slicing).
    let mut t = Table::new(&["path", "median", "p95", "iters"]);
    let mut exec_par =
        ModelExecutor::host_with_mode(WeightStore::synthetic(&m, 42), EngineMode::Parallel);
    run_batch(&mut exec_par, &toks, &hybrid, 2); // warm shards
    let par_t = bench("engine-parallel", 1, 1.0, || {
        std::hint::black_box(run_batch(&mut exec_par, &toks, &hybrid, 2));
    });
    let mut exec_seq =
        ModelExecutor::host_with_mode(WeightStore::synthetic(&m, 42), EngineMode::Sequential);
    run_batch(&mut exec_seq, &toks, &hybrid, 2);
    let seq_t = bench("engine-sequential", 1, 1.0, || {
        std::hint::black_box(run_batch(&mut exec_seq, &toks, &hybrid, 2));
    });
    let speedup = seq_t.median / par_t.median;
    for (name, timing) in [("parallel shards", &par_t), ("sequential shards", &seq_t)] {
        t.row(&[
            name.into(),
            hap::util::fmt_secs(timing.median),
            hap::util::fmt_secs(timing.p95),
            format!("{}", timing.iters),
        ]);
    }
    t.print();
    println!("parallel-vs-sequential shard execution: {speedup:.2}x");

    // --- Weight-upload amortization: fresh executor per batch (the
    // pre-refactor serve_workload behavior) vs one persistent executor.
    let batches = 4usize;
    let mut fresh_uploads = 0usize;
    for _ in 0..batches {
        let mut exec = ModelExecutor::host(WeightStore::synthetic(&m, 7));
        run_batch(&mut exec, &toks, &tp, 1);
        fresh_uploads += exec.stats().materializations;
    }
    let mut persistent = ModelExecutor::host(WeightStore::synthetic(&m, 7));
    for _ in 0..batches {
        run_batch(&mut persistent, &toks, &tp, 1);
    }
    let persistent_uploads = persistent.stats().materializations;
    assert_eq!(
        persistent_uploads * batches,
        fresh_uploads,
        "persistent executor should upload one batch's worth of shards once"
    );
    println!(
        "weight uploads over {batches} batches: fresh-per-batch {fresh_uploads} vs persistent {persistent_uploads}"
    );

    // --- Measured resharding work of one plan switch.
    let before = persistent.stats();
    run_batch(&mut persistent, &toks, &hybrid, 1);
    let after = persistent.stats();
    let switch_uploads = after.materializations - before.materializations;
    assert!(switch_uploads > 0, "plan switch moved no weights");
    assert_eq!(after.reshards, before.reshards + 1);
    println!(
        "plan switch TP4 -> EP2xTP2: {} shard uploads, {:.3} ms measured",
        switch_uploads,
        (after.reshard_seconds - before.reshard_seconds) * 1e3
    );

    let summary = Json::obj(vec![
        ("bench", "engine".into()),
        ("profile", "release".into()),
        (
            "parallel_vs_sequential",
            Json::obj(vec![
                ("parallel_median_s", par_t.median.into()),
                ("sequential_median_s", seq_t.median.into()),
                ("speedup", speedup.into()),
                ("devices", 4usize.into()),
            ]),
        ),
        (
            "weight_uploads",
            Json::obj(vec![
                ("batches", batches.into()),
                ("fresh_per_batch_total", fresh_uploads.into()),
                ("persistent_total", persistent_uploads.into()),
                (
                    "amortization",
                    (fresh_uploads as f64 / persistent_uploads.max(1) as f64).into(),
                ),
            ]),
        ),
        (
            "plan_switch",
            Json::obj(vec![
                ("uploads", switch_uploads.into()),
                (
                    "measured_s",
                    (after.reshard_seconds - before.reshard_seconds).into(),
                ),
            ]),
        ),
    ]);
    write_results("engine", &summary);
    let root_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_engine.json");
    if let Err(e) = std::fs::write(&root_path, summary.to_string_pretty()) {
        eprintln!("could not write {}: {e}", root_path.display());
    } else {
        println!("wrote {}", root_path.display());
    }
    println!("engine bench OK");
    Ok(())
}
