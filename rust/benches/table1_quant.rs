//! Paper Table I: quantization-scheme quality. The paper measures task
//! accuracy (MMLU/GSM8K/...) on Mixtral; without those corpora we use
//! the documented proxy (DESIGN.md §2): weight-space fidelity plus
//! *model-output* divergence (logit MSE + greedy-token agreement) of
//! the real tiny-MoE under each scheme applied to its expert weights.
//!
//! Shape to hold: per-group ≈ lossless (> per-tensor on every metric);
//! per-tensor visibly degrades the most sensitive metric.
//!
//! Also serves the real tiny-MoE end to end on the host backend under
//! `--quant int8|int4` (artifact-free) and scores greedy-token
//! agreement against the f32 engine.

mod common;

use hap::benchkit::{banner, write_results, Table};
use hap::model::{ModelExecutor, WeightStore};
use hap::quant::{self, QuantKind, Scheme};
use hap::runtime::TinyModelMeta;
use hap::serving::{serve_on, Request, ServeConfig};
use hap::util::json::Json;
use hap::util::rng::Rng;
use hap::util::stats;

fn main() -> anyhow::Result<()> {
    banner("table1", "quantization scheme quality (weight + output proxies)");

    // Weight-space metrics on synthetic Mixtral-like expert panels
    // (gaussian + outlier columns, which is what breaks per-tensor).
    let (rows, cols) = (512, 2048);
    let mut rng = Rng::new(42);
    let mut data = rng.normal_vec_f32(rows * cols, 0.02);
    // Sparse outlier channels (realistic LLM weight statistics): a few
    // per mille of values are 20σ — enough to blow up a global scale
    // while leaving most 128-groups clean.
    for r in (0..rows).step_by(16) {
        data[r * cols + (r * 7) % cols] = if r % 32 == 0 { 0.4 } else { -0.4 };
    }
    let schemes = [
        Scheme::PerTensor,
        Scheme::PerChannel,
        Scheme::PerGroup { group_size: 128 },
    ];
    let mut t = Table::new(&["scheme", "cosine sim", "rmse", "max err"]);
    let mut reports = Vec::new();
    for s in schemes {
        let rep = quant::evaluate(&data, rows, cols, s);
        t.row(&[
            rep.scheme.name(),
            format!("{:.5}", rep.cosine_similarity),
            format!("{:.3e}", rep.rmse),
            format!("{:.3e}", rep.max_abs_err),
        ]);
        reports.push(rep);
    }
    t.print();
    assert!(
        reports[2].rmse < reports[0].rmse,
        "per-group must beat per-tensor on rmse"
    );
    // Full Table I ordering: per-group ≻ per-channel ≻ per-tensor.
    assert!(reports[2].cosine_similarity > reports[1].cosine_similarity);
    assert!(reports[1].cosine_similarity > reports[0].cosine_similarity);
    // On this adversarial outlier-salted matrix per-group stays ≈0.995;
    // the paper's >99.5% claim is on real weights and is asserted below
    // on the tiny-MoE's actual expert tensors.
    assert!(reports[2].cosine_similarity > 0.99, "per-group degraded too far");

    // Output-level proxy on the real tiny-MoE (if artifacts exist):
    // quantize layer-0 expert weights, compare logits + greedy tokens.
    let dir = std::path::Path::new("artifacts");
    let mut json_extra = Vec::new();
    if dir.join("manifest.json").exists() {
        let rt = hap::runtime::PjrtRuntime::load(dir)?;
        let blob = rt.read_weights()?;
        let m = rt.manifest.model.clone();
        let tokens: Vec<i32> =
            (0..m.batch * m.prefill_len).map(|i| ((i * 37 + 11) % m.vocab) as i32).collect();

        // Baseline logits.
        let store = hap::model::WeightStore::from_blob(&rt.manifest, &blob)?;
        let _ = &store;
        let mut exec = hap::model::ModelExecutor::new(&rt)?;
        let base = exec.prefill(&tokens, &hap::model::ShardPlan::tp(1))?;
        let base_tok = hap::runtime::literal::argmax_rows(&base);

        let mut t2 = Table::new(&["scheme", "logit rmse", "greedy agreement"]);
        for s in schemes {
            // Quantize every layer's expert weights in a copy of the blob.
            let mut blob_q = blob.clone();
            for l in 0..m.layers {
                for name in ["wg", "wu", "wd"] {
                    let w = rt
                        .manifest
                        .weight(&format!("layer{l}.{name}"))
                        .expect("weight entry");
                    let n = w.elements();
                    let (r, c) = (n / m.inter, m.inter);
                    let q = quant::quantize(
                        &blob[w.offset_floats..w.offset_floats + n],
                        r,
                        c,
                        s,
                    );
                    let deq = quant::dequantize(&q);
                    blob_q[w.offset_floats..w.offset_floats + n].copy_from_slice(&deq);
                }
            }
            // Re-run prefill with quantized weights via a patched store.
            let store_q = hap::model::WeightStore::from_blob(&rt.manifest, &blob_q)?;
            let mut exec_q = hap::model::ModelExecutor::new(&rt)?;
            exec_q.weights = store_q;
            let got = exec_q.prefill(&tokens, &hap::model::ShardPlan::tp(1))?;
            let got_tok = hap::runtime::literal::argmax_rows(&got);
            let rmse = stats::rmse_f32(&base.data, &got.data);
            let agree = base_tok
                .iter()
                .zip(&got_tok)
                .filter(|(a, b)| a == b)
                .count() as f64
                / base_tok.len() as f64;
            if matches!(s, Scheme::PerGroup { .. }) {
                assert!(agree > 0.9, "per-group greedy agreement too low: {agree}");
            }
            t2.row(&[s.name(), format!("{rmse:.4}"), format!("{:.0}%", agree * 100.0)]);
            json_extra.push(Json::obj(vec![
                ("scheme", s.name().as_str().into()),
                ("logit_rmse", rmse.into()),
                ("greedy_agreement", agree.into()),
            ]));
        }
        println!("\nreal tiny-MoE output divergence (expert weights quantized):");
        t2.print();
    } else {
        println!("(artifacts/ not built — weight-space metrics only)");
    }

    // Output-level divergence, artifact-free: the packed host kernels
    // serve the same gang workload under f32 / int8 / int4 weights
    // (what `hap serve --backend host --quant ...` runs), and we score
    // the quantized runs by greedy-token agreement against f32. Runs
    // unconditionally — no artifacts/ gate.
    let meta = TinyModelMeta::host_demo();
    let workload = || -> Vec<Request> {
        (0..meta.batch as u64)
            .map(|i| {
                let prompt: Vec<i32> = (0..meta.prefill_len)
                    .map(|t| ((i as usize * 29 + t * 11 + 3) % meta.vocab) as i32)
                    .collect();
                Request::new(i, prompt, 12)
            })
            .collect()
    };
    let serve_tokens = |q: Option<QuantKind>| -> anyhow::Result<Vec<Vec<i32>>> {
        let mut cfg = ServeConfig::tp(4);
        cfg.quant = q;
        let mut exec = ModelExecutor::host(WeightStore::synthetic(&meta, 9));
        let mut rs = serve_on(&mut exec, &cfg, workload())?.responses;
        rs.sort_by_key(|r| r.id);
        Ok(rs.into_iter().map(|r| r.tokens).collect())
    };
    let base_toks = serve_tokens(None)?;
    assert!(base_toks.iter().all(|t| !t.is_empty()), "f32 host serving generated nothing");
    let mut t3 = Table::new(&["weights", "greedy agreement vs f32"]);
    let mut host_rows = Vec::new();
    for kind in [QuantKind::Int8, QuantKind::Int4] {
        let toks = serve_tokens(Some(kind))?;
        let (mut same, mut total) = (0usize, 0usize);
        for (a, b) in base_toks.iter().zip(&toks) {
            total += a.len().max(b.len());
            same += a.iter().zip(b).filter(|(x, y)| x == y).count();
        }
        let agree = same as f64 / total.max(1) as f64;
        t3.row(&[kind.name().into(), format!("{:.0}%", agree * 100.0)]);
        host_rows.push(Json::obj(vec![
            ("quant", kind.name().into()),
            ("greedy_agreement_vs_f32", agree.into()),
        ]));
    }
    println!("\nhost-backend quantized serving (synthetic tiny-MoE, artifact-free):");
    t3.print();

    write_results(
        "table1",
        &Json::obj(vec![
            (
                "weight_space",
                Json::Arr(
                    reports
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("scheme", r.scheme.name().as_str().into()),
                                ("cosine", r.cosine_similarity.into()),
                                ("rmse", r.rmse.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            ("output_proxy", Json::Arr(json_extra)),
            ("host_serving", Json::Arr(host_rows)),
        ]),
    );
    println!("table1 OK");
    Ok(())
}
