//! Paper Fig 6: short-context (256) / extended-generation (2048)
//! speedups. Decode-dominated: the planner should pick TP-like expert
//! configs for decode and HAP ≈ TP (paper: ≤1.01–1.23×).

mod common;

use common::{report, speedup_row, BATCHES};
use hap::config::{MoEModelConfig, NodeConfig, Scenario};
use hap::planner::HapPlanner;

fn main() -> anyhow::Result<()> {
    for node in [NodeConfig::a6000x(4), NodeConfig::a100x(4)] {
        let mut rows = Vec::new();
        for model in MoEModelConfig::paper_models() {
            for b in BATCHES {
                let sc = Scenario::short_extended().with_batch(b);
                rows.push(speedup_row(&model, &node, &sc, 1)?);
            }
        }
        report(
            &format!("fig6_{}", node.label()),
            &format!("short ctx (256) / extended gen (2048) on {}", node.label()),
            &rows,
        );
        for r in &rows {
            assert!(r.speedup > 0.95, "HAP lost badly: {} {}", r.model, r.speedup);
            assert!(r.speedup < 1.6, "implausible speedup in decode-bound scenario: {}", r.speedup);
        }
    }
    // Decode-dominated ⇒ expert decode strategy should be TP.
    let model = MoEModelConfig::mixtral_8x7b();
    let node = NodeConfig::a6000x(4);
    let planner = HapPlanner::new(&model, &node);
    let plan = planner.plan(&Scenario::short_extended(), 2048)?;
    assert_eq!(plan.expert_decode.ep, 1, "decode should favor TP: {plan}");
    println!("fig6 OK (decode picks {})", plan.expert_decode);
    Ok(())
}
