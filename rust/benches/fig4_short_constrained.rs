//! Paper Fig 4: short-context (256) / constrained-generation (64)
//! speedups of HAP vs static TP for the three Table III models on
//! 4×A6000 and 4×A100, across batch sizes.
//!
//! Shape to hold: HAP ≥ TP everywhere (never loses); modest max
//! speedups (paper: up to 1.13–1.18× on A6000, 1.11–1.37× on A100).

mod common;

use common::{report, speedup_row, BATCHES};
use hap::config::{MoEModelConfig, NodeConfig, Scenario};

fn main() -> anyhow::Result<()> {
    for node in [NodeConfig::a6000x(4), NodeConfig::a100x(4)] {
        let mut rows = Vec::new();
        for model in MoEModelConfig::paper_models() {
            for b in BATCHES {
                let sc = Scenario::short_constrained().with_batch(b);
                rows.push(speedup_row(&model, &node, &sc, 1)?);
            }
        }
        report(
            &format!("fig4_{}", node.label()),
            &format!("short ctx (256) / constrained gen (64) on {}", node.label()),
            &rows,
        );
        for r in &rows {
            assert!(r.speedup > 0.97, "HAP lost to TP: {} {} {}", r.model, r.batch, r.speedup);
        }
    }
    println!("fig4 OK");
    Ok(())
}
