//! Shared helpers for the paper-figure benches.

use hap::benchkit::Table;
use hap::config::{MoEModelConfig, NodeConfig, Scenario};
use hap::engine::Engine;
use hap::planner::HapPlanner;
use hap::strategy::{AttnStrategy, ExpertStrategy};
use hap::util::json::Json;

/// Measured (cluster-simulator) TP-baseline end-to-end latency.
pub fn measured_tp(model: &MoEModelConfig, node: &NodeConfig, sc: &Scenario, seed: u64) -> f64 {
    let engine = Engine::new(model, node);
    let n = node.num_devices;
    engine
        .run_static(&AttnStrategy::new(n, 1), &ExpertStrategy::new(n, 1), sc, seed)
        .total()
}

/// One figure row: plan with HAP, measure both on the engine.
pub struct SpeedupRow {
    pub model: String,
    pub scenario: String,
    pub batch: usize,
    pub tp_s: f64,
    pub hap_s: f64,
    pub speedup: f64,
    pub plan: String,
}

pub fn speedup_row(
    model: &MoEModelConfig,
    node: &NodeConfig,
    sc: &Scenario,
    seed: u64,
) -> anyhow::Result<SpeedupRow> {
    let planner = HapPlanner::new(model, node);
    let engine = Engine::new(model, node);
    let plan = planner.plan(sc, sc.generate)?;
    let tp_s = measured_tp(model, node, sc, seed);
    let hap_s = engine.run_plan(&plan, sc, seed).total();
    Ok(SpeedupRow {
        model: model.name.clone(),
        scenario: sc.name.clone(),
        batch: sc.batch,
        tp_s,
        hap_s,
        speedup: tp_s / hap_s,
        plan: plan.signature(),
    })
}

/// Render speedup rows as a paper-style table + JSON dump.
pub fn report(id: &str, what: &str, rows: &[SpeedupRow]) {
    hap::benchkit::banner(id, what);
    let mut t = Table::new(&["model", "scenario", "batch", "TP (s)", "HAP (s)", "speedup", "plan"]);
    let mut json_rows = Vec::new();
    for r in rows {
        t.row(&[
            r.model.clone(),
            r.scenario.clone(),
            format!("{}", r.batch),
            format!("{:.3}", r.tp_s),
            format!("{:.3}", r.hap_s),
            format!("{:.2}x", r.speedup),
            r.plan.clone(),
        ]);
        json_rows.push(Json::obj(vec![
            ("model", r.model.as_str().into()),
            ("scenario", r.scenario.as_str().into()),
            ("batch", r.batch.into()),
            ("tp_s", r.tp_s.into()),
            ("hap_s", r.hap_s.into()),
            ("speedup", r.speedup.into()),
            ("plan", r.plan.as_str().into()),
        ]));
    }
    t.print();
    let max = rows.iter().map(|r| r.speedup).fold(0.0, f64::max);
    let min = rows.iter().map(|r| r.speedup).fold(f64::INFINITY, f64::min);
    println!("speedup range: {min:.2}x – {max:.2}x");
    hap::benchkit::write_results(id, &Json::obj(vec![("rows", Json::Arr(json_rows))]));
}

/// The batch sizes the paper's per-figure bars sweep.
pub const BATCHES: [usize; 3] = [8, 16, 32];
