//! §Observability benchmark — BENCH_obs.json at the repo root.
//!
//! The Fig-2-style per-module time breakdown, **measured from the
//! trace recorder** instead of the simulator: two traced streaming
//! serves on the host grid engine — static TP4 vs the HAP phase
//! transition (EP prefill → TP decode) — folded by `summarize_lines`
//! into attention / expert-FFN / collective / reshard shares, next to
//! the discrete-event simulator's predicted shares for the same
//! strategy pairs on the same tiny-MoE deployment. The hybrid run
//! must pay reshard work the static run doesn't (the transition's
//! cost, visible only in the measured column: the static sim path has
//! no reshard bucket), and the trace must be deterministic — two
//! identical seeded runs agree byte for byte on the canonical stream.

use hap::benchkit::{banner, write_results, Table};
use hap::config::{MoEModelConfig, NodeConfig, Scenario};
use hap::engine::Breakdown;
use hap::model::{ModelExecutor, WeightStore};
use hap::obs::{canonical_stream, events_to_jsonl, summarize_lines, Recorder, TraceSummary};
use hap::runtime::TinyModelMeta;
use hap::serving::{serve_with_recorder, Request, Scheduling, ServeConfig, ServeReport};
use hap::strategy::{AttnStrategy, ExpertStrategy};
use hap::util::json::Json;
use hap::util::rng::Rng;

const REQUESTS: usize = 24;
/// Generation lengths 2–8: short decodes keep admissions (and so the
/// hybrid run's per-boundary expert reshards) frequent.
const GEN_LO: usize = 2;
const GEN_HI: usize = 8;

fn meta() -> TinyModelMeta {
    TinyModelMeta::host_demo()
}

fn requests(m: &TinyModelMeta, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..REQUESTS as u64)
        .map(|id| {
            let len = rng.range(m.prefill_len / 2, m.prefill_len);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(m.vocab) as i32).collect();
            let gen = rng.range(GEN_LO, GEN_HI);
            Request::new(id, prompt, gen)
        })
        .collect()
}

/// One traced streaming serve on a fresh host executor.
fn run(config: &ServeConfig, seed: u64) -> ServeReport {
    let m = meta();
    let mut exec = ModelExecutor::host(WeightStore::synthetic(&m, 42));
    serve_with_recorder(&mut exec, config, Scheduling::Streaming, requests(&m, seed), Recorder::new())
        .unwrap()
}

/// Fold a report's trace the same way `hap trace summarize` does.
fn fold(report: &ServeReport) -> TraceSummary {
    let jsonl = events_to_jsonl(&report.trace);
    let lines: Vec<Json> = jsonl.lines().map(|l| Json::parse(l).unwrap()).collect();
    summarize_lines(&lines)
}

/// Predicted shares in the trace summary's four-bucket layout from a
/// (prefill, decode) pair of simulator stage breakdowns. The static
/// sim path has no reshard bucket — the measured column is the only
/// place the transition's reshard cost can show up.
fn predicted_shares(prefill: &Breakdown, decode: &Breakdown) -> [(&'static str, f64); 4] {
    let attn = prefill.attn + decode.attn;
    let expert = prefill.expert + decode.expert;
    let comm = prefill.comm + decode.comm;
    let total = attn + expert + comm;
    let norm = |x: f64| if total > 0.0 { x / total } else { 0.0 };
    [
        ("attention", norm(attn)),
        ("expert_ffn", norm(expert)),
        ("collective", norm(comm)),
        ("reshard", 0.0),
    ]
}

fn shares_json(shares: &[(&'static str, f64); 4]) -> Json {
    Json::Obj(shares.iter().map(|(k, v)| (k.to_string(), Json::Num(*v))).collect())
}

fn share_row(t: &mut Table, name: &str, shares: &[(&'static str, f64); 4]) {
    let mut row = vec![name.to_string()];
    row.extend(shares.iter().map(|(_, s)| format!("{:.1}%", s * 100.0)));
    t.row(&row);
}

fn main() -> anyhow::Result<()> {
    banner("obs", "measured vs predicted per-module breakdown, TP4 vs hybrid, host engine");

    let tp = run(&ServeConfig::tp(4), 31);
    let hybrid = run(&ServeConfig::hap_transition(4), 31);

    // Determinism gate before anything else: an identical seeded rerun
    // must reproduce the TP trace byte for byte (wall fields stripped).
    let rerun = run(&ServeConfig::tp(4), 31);
    assert_eq!(
        canonical_stream(&events_to_jsonl(&tp.trace))?,
        canonical_stream(&events_to_jsonl(&rerun.trace))?,
        "canonical trace stream is not deterministic"
    );
    println!("trace determinism: rerun canonical stream bit-identical\n");

    let tp_sum = fold(&tp);
    let hy_sum = fold(&hybrid);

    // Simulator predictions for the same deployment (tiny-MoE on 4
    // simulated CPU devices) and the trace's traffic shape. The hybrid
    // pair = EP-expert prefill stage + TP-expert decode stage.
    let model = MoEModelConfig::tiny_moe();
    let node = NodeConfig::cpu_sim(4);
    let sim = hap::engine::Engine::new(&model, &node);
    let m = meta();
    let sc = Scenario::new("obs", m.prefill_len, (GEN_LO + GEN_HI) / 2, m.batch);
    let tp_sim = sim.run_static(&AttnStrategy::new(4, 1), &ExpertStrategy::new(4, 1), &sc, 1);
    let ep_sim = sim.run_static(&AttnStrategy::new(4, 1), &ExpertStrategy::new(1, 4), &sc, 1);
    let tp_pred = predicted_shares(&tp_sim.prefill, &tp_sim.decode);
    let hy_pred = predicted_shares(&ep_sim.prefill, &tp_sim.decode);

    let tp_shares = tp_sum.shares();
    let hy_shares = hy_sum.shares();
    let mut t = Table::new(&["run", "attention", "expert_ffn", "collective", "reshard"]);
    share_row(&mut t, "TP4 measured", &tp_shares);
    share_row(&mut t, "hybrid measured", &hy_shares);
    share_row(&mut t, "TP4 predicted", &tp_pred);
    share_row(&mut t, "hybrid predicted", &hy_pred);
    t.print();
    println!(
        "\nreshards: hybrid {} vs TP4 {} (metrics), {} Reshard trace events; \
         {} events / {} iterations traced per run",
        hybrid.metrics.reshards,
        tp.metrics.reshards,
        hy_sum.count("Reshard"),
        hy_sum.counts.iter().map(|(_, c)| c).sum::<usize>(),
        hy_sum.iterations,
    );

    let run_json = |report: &ServeReport, sum: &TraceSummary| {
        Json::obj(vec![
            ("events", (report.trace.len()).into()),
            ("iterations", (sum.iterations as f64).into()),
            ("decode_steps", sum.count("DecodeStep").into()),
            ("prefill_chunks", sum.count("PrefillChunk").into()),
            ("reshard_events", sum.count("Reshard").into()),
            ("reshards_total", report.metrics.reshards.into()),
            ("span_secs", sum.span_secs.into()),
            ("module_shares", shares_json(&sum.shares())),
            ("modules", sum.modules.to_json()),
        ])
    };
    let summary = Json::obj(vec![
        ("bench", "obs".into()),
        ("profile", "release".into()),
        (
            "trace",
            Json::obj(vec![
                ("requests", REQUESTS.into()),
                ("gen_lo", GEN_LO.into()),
                ("gen_hi", GEN_HI.into()),
                ("batch_slots", m.batch.into()),
                ("prompt_tokens", m.prefill_len.into()),
            ]),
        ),
        ("tp4_measured", run_json(&tp, &tp_sum)),
        ("hybrid_measured", run_json(&hybrid, &hy_sum)),
        ("tp4_predicted_shares", shares_json(&tp_pred)),
        ("hybrid_predicted_shares", shares_json(&hy_pred)),
    ]);
    write_results("obs", &summary);
    let root_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_obs.json");
    if let Err(e) = std::fs::write(&root_path, summary.to_string_pretty()) {
        eprintln!("could not write {}: {e}", root_path.display());
    } else {
        println!("wrote {}", root_path.display());
    }

    // Acceptance bars LAST, after the artifact is on disk.
    for (name, sum) in [("TP4", &tp_sum), ("hybrid", &hy_sum)] {
        assert_eq!(sum.count("Admit"), REQUESTS, "{name}: not every request admitted");
        assert_eq!(sum.count("Retire"), REQUESTS, "{name}: not every request retired");
        assert!(sum.count("DecodeStep") > 0, "{name}: no decode steps traced");
        assert!(sum.count("PrefillChunk") > 0, "{name}: no prefill ops traced");
        let total: f64 = sum.shares().iter().map(|(_, s)| s).sum();
        assert!((total - 1.0).abs() < 1e-9, "{name}: measured shares sum to {total}");
    }
    for (name, pred) in [("TP4", &tp_pred), ("hybrid", &hy_pred)] {
        let total: f64 = pred.iter().map(|(_, s)| s).sum();
        assert!((total - 1.0).abs() < 1e-9, "{name}: predicted shares sum to {total}");
    }
    assert!(
        hybrid.metrics.reshards > tp.metrics.reshards,
        "hybrid run must reshard experts at stage boundaries (hybrid {} vs TP4 {})",
        hybrid.metrics.reshards,
        tp.metrics.reshards,
    );
    assert!(
        hy_sum.count("Reshard") >= 1,
        "hybrid reshard work never reached the trace"
    );
    println!("obs bench OK");
    Ok(())
}
