//! Paper Fig 7: long-context (4096) / constrained-generation (64) —
//! HAP's best case. Prefill-dominated, so on PCIe the planner picks
//! low-communication configs (DP attention / EP experts) and wins big.
//!
//! Shape to hold: 1.21–1.68× on 4×A6000; up to 1.77× on 4×A100
//! (paper's numbers; ours should land in the same neighbourhood with
//! the biggest wins on the PCIe node).

mod common;

use common::{report, speedup_row};
use hap::config::{MoEModelConfig, NodeConfig, Scenario};

fn main() -> anyhow::Result<()> {
    let mut best = 0.0f64;
    for node in [NodeConfig::a6000x(4), NodeConfig::a100x(4)] {
        let mut rows = Vec::new();
        for model in MoEModelConfig::paper_models() {
            for b in [16, 32, 64] {
                let sc = Scenario::long_constrained().with_batch(b);
                rows.push(speedup_row(&model, &node, &sc, 1)?);
            }
        }
        report(
            &format!("fig7_{}", node.label()),
            &format!("long ctx (4096) / constrained gen (64) on {}", node.label()),
            &rows,
        );
        for r in &rows {
            assert!(r.speedup > 0.97, "HAP lost: {} {}", r.model, r.speedup);
            best = best.max(r.speedup);
        }
    }
    assert!(best > 1.2, "expected a substantial best-case win, got {best:.2}x");
    println!("fig7 OK (best {best:.2}x)");
    Ok(())
}
