//! §Fault-recovery benchmark — BENCH_fault_recovery.json at the repo
//! root.
//!
//! Measures the price of surviving a device crash, artifact-free:
//!
//!  - **crash-at-k vs no-fault**: the same workload on the streaming
//!    engine with and without a deterministic `crash@k` fault, plus a
//!    from-scratch run on the degraded-size grid as the lower bound —
//!    recovery latency in scheduler iterations and measured wall time,
//!    with the crash run's tokens asserted bit-identical to the
//!    degraded baseline (replay-from-prompt recovery);
//!  - **goodput**: generated tokens per second for each scenario;
//!  - **simulated degraded replay**: the trace-driven twin on the
//!    paper platform (mixtral-8x7b, 4×A6000) — makespan penalty of a
//!    mid-trace crash under the adaptive controller.

use hap::adapt::replay::{replay_adaptive, replay_adaptive_degraded, WorkloadTrace};
use hap::adapt::ControllerConfig;
use hap::benchkit::{banner, bench, write_results, Table};
use hap::config::{MoEModelConfig, NodeConfig};
use hap::model::{FaultPlan, WeightStore};
use hap::planner::HapPlanner;
use hap::runtime::TinyModelMeta;
use hap::serving::{Engine, Request, ServeConfig, ServeReport};
use hap::util::json::Json;
use hap::util::rng::Rng;

fn workload(m: &TinyModelMeta, n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n as u64)
        .map(|id| {
            let len = rng.range(m.prefill_len / 2, m.prefill_len);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(m.vocab) as i32).collect();
            let gen = rng.range(2, 8);
            Request::new(id, prompt, gen)
        })
        .collect()
}

fn sorted_tokens(report: &ServeReport) -> Vec<(u64, Vec<i32>)> {
    let mut t: Vec<(u64, Vec<i32>)> =
        report.responses.iter().map(|r| (r.id, r.tokens.clone())).collect();
    t.sort();
    t
}

/// Serve the standard workload, counting scheduler iterations to idle.
fn serve(
    m: &TinyModelMeta,
    tp: usize,
    fault: Option<&str>,
    n: usize,
) -> anyhow::Result<(usize, ServeReport)> {
    let mut builder = Engine::builder(ServeConfig::tp(tp));
    if let Some(trace) = fault {
        builder = builder.fault_plan(FaultPlan::parse_trace(trace)?);
    }
    let mut engine = builder.build_host(WeightStore::synthetic(m, 42));
    for req in workload(m, n, 5) {
        engine.submit(req)?;
    }
    let mut iters = 0usize;
    loop {
        let out = engine.step()?;
        iters += 1;
        if out.idle() {
            break;
        }
    }
    Ok((iters, engine.shutdown()?))
}

fn main() -> anyhow::Result<()> {
    banner("fault_recovery", "device-crash recovery: latency + goodput vs no-fault");
    let m = TinyModelMeta::host_demo();
    let n = 8usize;
    const CRASH: &str = "crash@6";

    // --- Correctness gate: the crash run recovers every request with
    // tokens bit-identical to the degraded-size grid run from scratch.
    let (iters_none, rep_none) = serve(&m, 4, None, n)?;
    let (iters_crash, rep_crash) = serve(&m, 4, Some(CRASH), n)?;
    let (iters_degraded, rep_degraded) = serve(&m, 2, None, n)?;
    assert_eq!(rep_none.metrics.requests_completed, n);
    assert_eq!(rep_crash.metrics.requests_completed, n, "crash run lost requests");
    assert_eq!(rep_crash.metrics.replans_degraded, 1, "crash must trigger one degraded re-plan");
    assert!(rep_crash.metrics.requests_recovered >= 1, "no request was recovered");
    assert_eq!(rep_crash.metrics.requests_failed, 0);
    assert_eq!(
        sorted_tokens(&rep_crash),
        sorted_tokens(&rep_degraded),
        "recovered tokens diverged from the degraded-grid baseline"
    );
    println!(
        "crash@6 on tp4: {} recovered, tokens == unfaulted tp2 run (bit-identical)",
        rep_crash.metrics.requests_recovered
    );

    // --- Wall time per scenario.
    let t_none = bench("fault-none-tp4", 1, 1.0, || {
        std::hint::black_box(serve(&m, 4, None, n).unwrap());
    });
    let t_crash = bench("fault-crash-at-6", 1, 1.0, || {
        std::hint::black_box(serve(&m, 4, Some(CRASH), n).unwrap());
    });
    let t_degraded = bench("fault-degraded-tp2", 1, 1.0, || {
        std::hint::black_box(serve(&m, 2, None, n).unwrap());
    });

    let goodput =
        |rep: &ServeReport, t: f64| rep.metrics.tokens_generated as f64 / t.max(1e-12);
    let mut table = Table::new(&["scenario", "sched iters", "median", "tok/s"]);
    for (name, iters, rep, t) in [
        ("no fault (tp4)", iters_none, &rep_none, &t_none),
        ("crash@6 → degraded tp2", iters_crash, &rep_crash, &t_crash),
        ("degraded baseline (tp2)", iters_degraded, &rep_degraded, &t_degraded),
    ] {
        table.row(&[
            name.into(),
            format!("{iters}"),
            hap::util::fmt_secs(t.median),
            format!("{:.0}", goodput(rep, t.median)),
        ]);
    }
    table.print();
    // Recovery latency: extra scheduler iterations over starting on
    // the degraded grid (requeue + replay + backoff accounting), and
    // over the unfaulted full grid.
    let recovery_iters = iters_crash.saturating_sub(iters_degraded);
    println!(
        "recovery latency: +{} iters vs degraded baseline, +{} iters vs no-fault",
        recovery_iters,
        iters_crash.saturating_sub(iters_none)
    );

    // --- Simulated twin on the paper platform: adaptive replay with a
    // mid-trace crash (4 → 2 devices) vs the no-fault adaptive run.
    let model = MoEModelConfig::mixtral_8x7b();
    let node = NodeConfig::a6000x(4);
    let planner = HapPlanner::new(&model, &node);
    let trace = WorkloadTrace::phase_shift(6, 16, 17);
    let cfg = ControllerConfig::default();
    let adaptive = replay_adaptive(&planner, &trace, &cfg, 32)?;
    let degraded = replay_adaptive_degraded(&planner, &trace, &cfg, 32, 6, 2)?;
    let penalty = degraded.total_s / adaptive.total_s - 1.0;
    println!(
        "simulated mid-trace crash (mixtral-8x7b, 4xA6000, batch 6/12): \
         {:.3} s vs {:.3} s no-fault ({:+.1}% makespan)",
        degraded.total_s,
        adaptive.total_s,
        penalty * 100.0
    );

    let summary = Json::obj(vec![
        ("bench", "fault_recovery".into()),
        ("profile", "release".into()),
        (
            "engine",
            Json::obj(vec![
                ("requests", n.into()),
                (
                    "no_fault",
                    Json::obj(vec![
                        ("sched_iters", iters_none.into()),
                        ("median_s", t_none.median.into()),
                        ("goodput_tok_s", goodput(&rep_none, t_none.median).into()),
                    ]),
                ),
                (
                    "crash_at_6",
                    Json::obj(vec![
                        ("sched_iters", iters_crash.into()),
                        ("median_s", t_crash.median.into()),
                        ("goodput_tok_s", goodput(&rep_crash, t_crash.median).into()),
                        ("faults_detected", rep_crash.metrics.faults_detected.into()),
                        ("replans_degraded", rep_crash.metrics.replans_degraded.into()),
                        ("requests_recovered", rep_crash.metrics.requests_recovered.into()),
                        ("requests_failed", rep_crash.metrics.requests_failed.into()),
                    ]),
                ),
                (
                    "degraded_baseline",
                    Json::obj(vec![
                        ("sched_iters", iters_degraded.into()),
                        ("median_s", t_degraded.median.into()),
                        ("goodput_tok_s", goodput(&rep_degraded, t_degraded.median).into()),
                    ]),
                ),
                ("recovery_latency_iters", recovery_iters.into()),
            ]),
        ),
        (
            "replay",
            Json::obj(vec![
                ("trace", "phase-shift".into()),
                ("crash_at_batch", 6usize.into()),
                ("survivors", 2usize.into()),
                ("adaptive_total_s", adaptive.total_s.into()),
                ("degraded_total_s", degraded.total_s.into()),
                ("makespan_penalty", penalty.into()),
            ]),
        ),
    ]);
    write_results("fault_recovery", &summary);
    let root_path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_fault_recovery.json");
    if let Err(e) = std::fs::write(&root_path, summary.to_string_pretty()) {
        eprintln!("could not write {}: {e}", root_path.display());
    } else {
        println!("wrote {}", root_path.display());
    }
    println!("fault_recovery bench OK");
    Ok(())
}
