//! §Pipeline benchmark — BENCH_pipeline.json at the repo root.
//!
//! Measures the micro-chunk pipelined iteration loop (ISSUE 10) on a
//! comm-heavy hybrid plan (attn TP4, experts TP2×EP2 — the EP combine
//! is the per-layer communication the pipeline hides):
//!
//! - **iteration-time win**: the same prefill + decode workload at
//!   `K = 1` (module-sequential) vs `K = 4` micro-chunks, equal tokens,
//!   equal threads (both `EngineMode::Parallel`) — only the overlap
//!   differs;
//! - **bit-identity gate**: the pipelined streaming engine's tokens vs
//!   the `EngineMode::Sequential` oracle;
//! - **overlap-model accuracy**: [`OverlapModel::fit`] over measured
//!   `(compute, comm, span)` samples at three workload scales, then
//!   predicted vs measured overlap share on the main workload;
//! - **planner evidence**: a planner carrying an overlap model prices
//!   the active comm pair as `max + ε·min` and selects plans flagged
//!   `exec=pipelined` — plans the non-overlap planner cannot choose —
//!   at a predicted total never above the sequential planner's.

use hap::benchkit::{banner, write_results, Table};
use hap::config::{MoEModelConfig, NodeConfig, Scenario};
use hap::model::{EngineMode, ModelExecutor, ShardPlan, WeightStore};
use hap::obs::ModuleTimes;
use hap::planner::HapPlanner;
use hap::runtime::TinyModelMeta;
use hap::serving::{Engine, Request, ServeConfig};
use hap::sim::OverlapModel;
use hap::strategy::{AttnStrategy, ExpertStrategy};
use hap::util::json::Json;
use std::time::Instant;

/// A host-demo-shaped model scaled up until one iteration is long
/// enough to time: the comparison is compute-vs-combine overlap, so it
/// needs real work per chunk, not microsecond noise.
fn bench_meta() -> TinyModelMeta {
    let mut m = TinyModelMeta::host_demo();
    m.hidden = 128;
    m.q_heads = 16;
    m.inter = 256;
    m.layers = 4;
    m.batch = 8;
    m.prefill_len = 32;
    // Room for the deepest decode sweep (24 steps past the prefill).
    m.max_len = 64;
    m
}

/// Comm-heavy hybrid plan: TP4 attention, TP2×EP2 experts — every
/// expert layer ends in an EP contribution-combine for the pipeline to
/// hide under the next chunk's FFN.
fn hybrid_plan() -> ShardPlan {
    ShardPlan::new(AttnStrategy::new(4, 1), ExpertStrategy::new(2, 2))
}

/// One timed iteration workload: a full gang prefill plus `decodes`
/// decode steps at micro-chunk width `k`. Returns the wall seconds and
/// the executor's ModuleTimes delta over the timed region (median-wall
/// rep of `reps`).
fn measure(m: &TinyModelMeta, k: usize, decodes: usize, reps: usize) -> (f64, ModuleTimes) {
    let plan = hybrid_plan();
    let toks: Vec<i32> =
        (0..(m.batch * m.prefill_len) as i32).map(|i| i % m.vocab as i32).collect();
    let mut exec = ModelExecutor::host(WeightStore::synthetic(m, 42));
    exec.set_pipeline_chunks(k).unwrap();
    exec.prefill(&toks, &plan).unwrap(); // warm: shards go resident
    let mut runs: Vec<(f64, ModuleTimes)> = (0..reps)
        .map(|_| {
            let base = exec.module_times().clone();
            let t0 = Instant::now();
            exec.prefill(&toks, &plan).unwrap();
            for _ in 0..decodes {
                exec.decode_step(&vec![1; m.batch], &plan).unwrap();
            }
            (t0.elapsed().as_secs_f64(), exec.module_times().delta_since(&base))
        })
        .collect();
    runs.sort_by(|a, b| a.0.total_cmp(&b.0));
    runs.swap_remove(runs.len() / 2)
}

/// Streaming-engine token identity: pipelined `K = 4` vs the
/// module-sequential oracle, on the hybrid transition config.
fn tokens_bit_identical(m: &TinyModelMeta) -> bool {
    let run = |mode: EngineMode, k: usize| {
        let mut config = ServeConfig::hap_transition(4);
        config.pipeline_chunks = k;
        let mut engine =
            Engine::builder(config).build_host_with_mode(WeightStore::synthetic(m, 42), mode);
        for id in 0..6u64 {
            let len = m.prefill_len / 2 + (id as usize * 3) % (m.prefill_len / 2);
            let prompt: Vec<i32> =
                (0..len).map(|i| ((i as u64 * 7 + id * 13) % m.vocab as u64) as i32).collect();
            engine.submit(Request::new(id, prompt, 4)).unwrap();
        }
        let report = engine.shutdown().unwrap();
        let mut t: Vec<(u64, Vec<i32>)> =
            report.responses.iter().map(|r| (r.id, r.tokens.clone())).collect();
        t.sort();
        t
    };
    run(EngineMode::Sequential, 1) == run(EngineMode::Parallel, 4)
}

fn main() -> anyhow::Result<()> {
    banner("pipeline", "micro-chunk pipelined iteration: measured win, overlap fit, planner");
    let m = bench_meta();
    const K: usize = 4;
    const DECODES: usize = 16;
    const REPS: usize = 5;

    // --- Correctness gate first: overlap must be latency-only.
    let bit_identical = tokens_bit_identical(&m);
    assert!(bit_identical, "pipelined tokens diverged from the sequential oracle");

    // --- Iteration-time win at equal tokens and equal threading.
    let (w_seq, t_seq) = measure(&m, 1, DECODES, REPS);
    let (w_pipe, t_pipe) = measure(&m, K, DECODES, REPS);
    let speedup = w_seq / w_pipe;
    // Expert-section span: total wall minus the (K-invariant) non-expert
    // time, estimated from the K = 1 run where the section is exactly
    // compute + combine.
    let non_expert = (w_seq - (t_seq.expert_s + t_seq.collective_s)).max(0.0);
    let span_pipe = (w_pipe - non_expert).max(0.0);

    // --- Overlap model: fit on three workload scales, then compare
    // predicted vs measured overlap share on the main workload.
    let mut samples: Vec<(f64, f64, f64)> = Vec::new();
    for decodes in [4usize, 12, 24] {
        let (w1, t1) = measure(&m, 1, decodes, REPS);
        let (wk, _) = measure(&m, K, decodes, REPS);
        let base = (w1 - (t1.expert_s + t1.collective_s)).max(0.0);
        samples.push((t1.expert_s, t1.collective_s, (wk - base).max(0.0)));
    }
    let om = OverlapModel::fit(&samples);
    let (e, c) = (t_seq.expert_s, t_seq.collective_s);
    let hidden = e.min(c).max(1e-12);
    let measured_share = (((e + c) - span_pipe) / hidden).clamp(0.0, 1.0);
    let predicted_share = (((e + c) - om.overlapped(e, c)) / hidden).clamp(0.0, 1.0);
    let share_error = (measured_share - predicted_share).abs();

    // --- Planner: the overlap-aware planner selects pipelined plans
    // the sequential-cost planner cannot express, never at a worse
    // predicted total.
    let model = MoEModelConfig::mixtral_8x7b();
    let node = NodeConfig::a6000x(4);
    let seq_planner = HapPlanner::new(&model, &node);
    let pipe_planner = HapPlanner::new(&model, &node).with_overlap(OverlapModel::new(0.25, 0.0));
    let mut planner_rows: Vec<Json> = Vec::new();
    let mut any_pipelined = false;
    for sc in Scenario::table2() {
        let seq_plan = seq_planner.plan(&sc, sc.generate)?;
        let pipe_plan = pipe_planner.plan(&sc, sc.generate)?;
        assert!(
            pipe_plan.predicted_total <= seq_plan.predicted_total * (1.0 + 1e-9),
            "{}: overlap-aware planner lost ground ({} vs {})",
            sc.name,
            pipe_plan.predicted_total,
            seq_plan.predicted_total
        );
        let pipelined = pipe_plan.pipelined_prefill || pipe_plan.pipelined_decode;
        any_pipelined |= pipelined;
        planner_rows.push(Json::obj(vec![
            ("scenario", sc.name.as_str().into()),
            ("seq_signature", seq_plan.signature().into()),
            ("pipe_signature", pipe_plan.signature().into()),
            ("seq_predicted_total_s", seq_plan.predicted_total.into()),
            ("pipe_predicted_total_s", pipe_plan.predicted_total.into()),
            ("pipelined_prefill", pipe_plan.pipelined_prefill.into()),
            ("pipelined_decode", pipe_plan.pipelined_decode.into()),
            (
                "strategy_changed",
                (seq_plan.attn != pipe_plan.attn
                    || seq_plan.expert_prefill != pipe_plan.expert_prefill
                    || seq_plan.expert_decode != pipe_plan.expert_decode)
                    .into(),
            ),
        ]));
    }
    assert!(
        any_pipelined,
        "the overlap-aware planner never flagged a pipelined stage across Table II"
    );

    let mut table = Table::new(&["metric", "value"]);
    table.row(&["K (micro-chunks)".into(), format!("{K}")]);
    table.row(&["wall K=1".into(), hap::util::fmt_secs(w_seq)]);
    table.row(&[format!("wall K={K}"), hap::util::fmt_secs(w_pipe)]);
    table.row(&["speedup".into(), format!("{speedup:.3}x")]);
    table.row(&["fitted eps".into(), format!("{:.3}", om.eps)]);
    table.row(&["measured overlap share".into(), format!("{measured_share:.3}")]);
    table.row(&["predicted overlap share".into(), format!("{predicted_share:.3}")]);
    table.row(&["share error".into(), format!("{share_error:.3}")]);
    table.row(&["tokens bit-identical".into(), format!("{bit_identical}")]);
    table.print();

    let summary = Json::obj(vec![
        ("bench", "pipeline".into()),
        ("profile", "release".into()),
        ("plan", hybrid_plan().label().into()),
        ("pipeline_chunks", K.into()),
        ("decode_iters", DECODES.into()),
        ("wall_sequential_s", w_seq.into()),
        ("wall_pipelined_s", w_pipe.into()),
        ("speedup", speedup.into()),
        ("measured_win", (speedup > 1.0).into()),
        ("tokens_bit_identical", bit_identical.into()),
        (
            "overlap",
            Json::obj(vec![
                ("eps", om.eps.into()),
                ("overhead_s", om.overhead.into()),
                ("expert_s", e.into()),
                ("collective_s", c.into()),
                ("span_pipelined_s", span_pipe.into()),
                ("measured_share", measured_share.into()),
                ("predicted_share", predicted_share.into()),
                ("share_error", share_error.into()),
            ]),
        ),
        ("planner", Json::Arr(planner_rows)),
        ("planner_selects_pipelined", any_pipelined.into()),
    ]);
    write_results("pipeline", &summary);
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_pipeline.json");
    if let Err(e) = std::fs::write(&root, summary.to_string_pretty()) {
        eprintln!("could not write {}: {e}", root.display());
    } else {
        println!("wrote {}", root.display());
    }
    println!("pipeline bench OK");
    Ok(())
}
