//! §Serving-API benchmark — BENCH_serving_api.json at the repo root.
//!
//! Streaming engine (continuous batching) vs the gang-scheduled compat
//! wrapper on a mixed short/long trace, artifact-free on the host grid
//! engine: interleaved 2-token and 24-token requests make gang batches
//! convoy behind their slowest member, while the streaming scheduler
//! retires short requests and backfills their slots mid-decode.
//! Reported: throughput, mean/p95 TTFT, mean/p95 latency, TPOT, slot
//! occupancy, decode-step counts, and weight uploads (which must stay
//! flat across iterations under the fixed plan). Token equality between
//! the two modes is asserted before anything is timed.

use hap::benchkit::{banner, write_results, Table};
use hap::model::ModelExecutor;
use hap::runtime::TinyModelMeta;
use hap::serving::{serve_with, Metrics, Request, Scheduling, ServeConfig, ServeReport};
use hap::util::json::Json;
use hap::util::rng::Rng;

const SHORT_GEN: usize = 2;
const LONG_GEN: usize = 24;
const REQUESTS: usize = 24;

fn meta() -> TinyModelMeta {
    TinyModelMeta::host_demo()
}

/// Interleaved short/long trace: every other request is a quick
/// completion whose gang slot rides dead for `LONG_GEN - SHORT_GEN`
/// decode steps.
fn trace(m: &TinyModelMeta, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..REQUESTS as u64)
        .map(|id| {
            let len = rng.range(m.prefill_len / 2, m.prefill_len);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(m.vocab) as i32).collect();
            let gen = if id % 2 == 0 { SHORT_GEN } else { LONG_GEN };
            Request::new(id, prompt, gen)
        })
        .collect()
}

fn run(scheduling: Scheduling, seed: u64) -> ServeReport {
    let m = meta();
    let weights = hap::model::WeightStore::synthetic(&m, 42);
    let mut exec = ModelExecutor::host(weights);
    let config = ServeConfig::tp(4);
    serve_with(&mut exec, &config, scheduling, trace(&m, seed)).unwrap()
}

fn row(t: &mut Table, name: &str, m: &Metrics) {
    t.row(&[
        name.into(),
        format!("{:.1}", m.throughput()),
        format!("{:.1}", m.mean_ttft() * 1e3),
        format!("{:.1}", m.ttft_p(95.0) * 1e3),
        format!("{:.1}", m.mean_latency() * 1e3),
        format!("{:.1}", m.latency_p(95.0) * 1e3),
        format!("{:.0}%", m.mean_occupancy() * 100.0),
        format!("{}", m.decode_steps),
    ]);
}

fn metrics_json(m: &Metrics) -> Json {
    Json::obj(vec![
        ("throughput_tok_s", m.throughput().into()),
        ("ttft_mean_s", m.mean_ttft().into()),
        ("ttft_p95_s", m.ttft_p(95.0).into()),
        ("latency_mean_s", m.mean_latency().into()),
        ("latency_p95_s", m.latency_p(95.0).into()),
        ("tpot_p50_s", m.tpot_p(50.0).into()),
        ("occupancy", m.mean_occupancy().into()),
        ("decode_steps", m.decode_steps.into()),
        ("weight_uploads", m.weight_uploads.into()),
    ])
}

fn main() -> anyhow::Result<()> {
    banner(
        "serving_api",
        "streaming engine vs gang scheduling on a mixed short/long trace",
    );

    // Correctness gate before timing: same tokens either way.
    let gang0 = run(Scheduling::Gang, 3);
    let stream0 = run(Scheduling::Streaming, 3);
    let key = |r: &ServeReport| {
        let mut t: Vec<(u64, Vec<i32>)> =
            r.responses.iter().map(|x| (x.id, x.tokens.clone())).collect();
        t.sort();
        t
    };
    assert_eq!(key(&gang0), key(&stream0), "scheduling changed generated tokens");
    println!("streaming == gang tokens (bit-identical per request)");

    // Timed runs (fresh executors; cold shard upload included in both).
    let gang = run(Scheduling::Gang, 17);
    let streaming = run(Scheduling::Streaming, 17);

    let mut t = Table::new(&[
        "engine",
        "tok/s",
        "ttft mean (ms)",
        "ttft p95 (ms)",
        "lat mean (ms)",
        "lat p95 (ms)",
        "occupancy",
        "decode steps",
    ]);
    row(&mut t, "gang", &gang.metrics);
    row(&mut t, "streaming", &streaming.metrics);
    t.print();

    let gm = &gang.metrics;
    let sm = &streaming.metrics;
    // The acceptance bar: convoy elimination shows up as better mean
    // TTFT and better tail latency on the mixed trace, with weight
    // uploads flat (one layout's worth) for both fixed-plan runs.
    assert!(
        sm.mean_ttft() < gm.mean_ttft(),
        "streaming mean TTFT {:.4}s not better than gang {:.4}s",
        sm.mean_ttft(),
        gm.mean_ttft()
    );
    assert!(
        sm.latency_p(95.0) < gm.latency_p(95.0),
        "streaming p95 latency {:.4}s not better than gang {:.4}s",
        sm.latency_p(95.0),
        gm.latency_p(95.0)
    );
    assert_eq!(
        sm.weight_uploads, gm.weight_uploads,
        "fixed-plan runs must upload exactly one layout's worth of shards"
    );
    println!(
        "mean TTFT {:.2}x better, p95 latency {:.2}x better, {} vs {} decode steps",
        gm.mean_ttft() / sm.mean_ttft(),
        gm.latency_p(95.0) / sm.latency_p(95.0),
        sm.decode_steps,
        gm.decode_steps,
    );

    let summary = Json::obj(vec![
        ("bench", "serving_api".into()),
        ("profile", "release".into()),
        (
            "trace",
            Json::obj(vec![
                ("requests", REQUESTS.into()),
                ("short_gen", SHORT_GEN.into()),
                ("long_gen", LONG_GEN.into()),
                ("batch_slots", meta().batch.into()),
            ]),
        ),
        ("gang", metrics_json(gm)),
        ("streaming", metrics_json(sm)),
        (
            "improvement",
            Json::obj(vec![
                ("ttft_mean", (gm.mean_ttft() / sm.mean_ttft()).into()),
                ("latency_p95", (gm.latency_p(95.0) / sm.latency_p(95.0)).into()),
                ("throughput", (sm.throughput() / gm.throughput().max(1e-12)).into()),
            ]),
        ),
    ]);
    write_results("serving_api", &summary);
    let root_path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_serving_api.json");
    if let Err(e) = std::fs::write(&root_path, summary.to_string_pretty()) {
        eprintln!("could not write {}: {e}", root_path.display());
    } else {
        println!("wrote {}", root_path.display());
    }
    println!("serving_api bench OK");
    Ok(())
}
