//! §Serving-API benchmark — BENCH_serving_api.json at the repo root.
//!
//! Streaming engine (continuous batching) vs the gang-scheduled compat
//! wrapper on a mixed short/long trace, artifact-free on the host grid
//! engine: interleaved 2-token and 24-token requests make gang batches
//! convoy behind their slowest member, while the streaming scheduler
//! retires short requests and backfills their slots mid-decode.
//! Reported: throughput, mean/p95 TTFT, mean/p95 latency, TPOT, slot
//! occupancy, decode-step counts, and weight uploads (which must stay
//! flat across iterations under the fixed plan). Token equality between
//! the two modes is asserted before anything is timed.
//!
//! Second section: **chunked prefill** on a mixed long-prompt/
//! short-decode trace (64-token padded prompts, 2–6 token
//! generations). Unchunked streaming still head-of-line-blocks peers
//! for a whole prompt at every admission; with `prefill_chunk` the
//! prompt spreads across iterations and short-decode peers escape
//! between chunks, improving their p95 TPOT — asserted, with tokens
//! bit-identical to gang and to unchunked streaming.
//!
//! Third section: **recorder overhead**. The same streaming run with
//! an enabled trace `Recorder` vs `Recorder::disabled()` — tokens must
//! be bit-identical and the instrumented median wall time within 5% of
//! the uninstrumented one (medians of five runs per mode).

use hap::benchkit::{banner, write_results, Table};
use hap::model::ModelExecutor;
use hap::obs::Recorder;
use hap::runtime::TinyModelMeta;
use hap::serving::{
    serve_with, serve_with_recorder, Metrics, Request, Scheduling, ServeConfig, ServeReport,
};
use hap::util::json::Json;
use hap::util::rng::Rng;

const SHORT_GEN: usize = 2;
const LONG_GEN: usize = 24;
const REQUESTS: usize = 24;
/// Chunked-prefill section: prompt tokens per joiner per iteration.
const PREFILL_CHUNK: usize = 8;
const LONG_PROMPT_REQUESTS: usize = 24;

fn meta() -> TinyModelMeta {
    TinyModelMeta::host_demo()
}

/// Long-prompt model shape for the chunked-prefill section: 64-token
/// padded prompts make one admission's prefill dwarf a decode step.
fn long_prompt_meta() -> TinyModelMeta {
    TinyModelMeta { prefill_len: 64, max_len: 96, ..TinyModelMeta::host_demo() }
}

/// Interleaved short/long trace: every other request is a quick
/// completion whose gang slot rides dead for `LONG_GEN - SHORT_GEN`
/// decode steps.
fn trace(m: &TinyModelMeta, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..REQUESTS as u64)
        .map(|id| {
            let len = rng.range(m.prefill_len / 2, m.prefill_len);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(m.vocab) as i32).collect();
            let gen = if id % 2 == 0 { SHORT_GEN } else { LONG_GEN };
            Request::new(id, prompt, gen)
        })
        .collect()
}

fn run(scheduling: Scheduling, seed: u64) -> ServeReport {
    let m = meta();
    let weights = hap::model::WeightStore::synthetic(&m, 42);
    let mut exec = ModelExecutor::host(weights);
    let config = ServeConfig::tp(4);
    serve_with(&mut exec, &config, scheduling, trace(&m, seed)).unwrap()
}

/// Mixed long-prompt/short-decode trace: every prompt pads to the full
/// 64 tokens (prefill-heavy), generations stay short (2–6), so peers
/// finish mid-way through a joiner's prefill window.
fn long_trace(m: &TinyModelMeta, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..LONG_PROMPT_REQUESTS as u64)
        .map(|id| {
            let len = rng.range(m.prefill_len / 2, m.prefill_len);
            let prompt: Vec<i32> = (0..len).map(|_| rng.below(m.vocab) as i32).collect();
            let gen = rng.range(2, 6);
            Request::new(id, prompt, gen)
        })
        .collect()
}

/// Long-prompt trace under a given scheduler/chunk size (0 = unchunked).
fn run_long(scheduling: Scheduling, chunk: usize, seed: u64) -> ServeReport {
    let m = long_prompt_meta();
    let weights = hap::model::WeightStore::synthetic(&m, 42);
    let mut exec = ModelExecutor::host(weights);
    let mut config = ServeConfig::tp(4);
    config.prefill_chunk = chunk;
    serve_with(&mut exec, &config, scheduling, long_trace(&m, seed)).unwrap()
}

/// Median of timing samples — every wall-clock inequality this bench
/// gates CI on is compared on medians over three runs, so one noisy
/// shared-runner sample cannot flip it.
fn median(mut v: Vec<f64>) -> f64 {
    v.sort_by(|a, b| a.partial_cmp(b).expect("finite timing sample"));
    v[v.len() / 2]
}

fn row(t: &mut Table, name: &str, m: &Metrics) {
    t.row(&[
        name.into(),
        format!("{:.1}", m.throughput()),
        format!("{:.1}", m.mean_ttft() * 1e3),
        format!("{:.1}", m.ttft_p(95.0) * 1e3),
        format!("{:.1}", m.mean_latency() * 1e3),
        format!("{:.1}", m.latency_p(95.0) * 1e3),
        format!("{:.0}%", m.mean_occupancy() * 100.0),
        format!("{}", m.decode_steps),
    ]);
}

fn metrics_json(m: &Metrics) -> Json {
    Json::obj(vec![
        ("throughput_tok_s", m.throughput().into()),
        ("ttft_mean_s", m.mean_ttft().into()),
        ("ttft_p95_s", m.ttft_p(95.0).into()),
        ("latency_mean_s", m.mean_latency().into()),
        ("latency_p95_s", m.latency_p(95.0).into()),
        ("tpot_p50_s", m.tpot_p(50.0).into()),
        ("occupancy", m.mean_occupancy().into()),
        ("decode_steps", m.decode_steps.into()),
        ("weight_uploads", m.weight_uploads.into()),
    ])
}

fn main() -> anyhow::Result<()> {
    banner(
        "serving_api",
        "streaming engine vs gang scheduling on a mixed short/long trace",
    );

    // Correctness gate before timing: same tokens either way.
    let gang0 = run(Scheduling::Gang, 3);
    let stream0 = run(Scheduling::Streaming, 3);
    let key = |r: &ServeReport| {
        let mut t: Vec<(u64, Vec<i32>)> =
            r.responses.iter().map(|x| (x.id, x.tokens.clone())).collect();
        t.sort();
        t
    };
    assert_eq!(key(&gang0), key(&stream0), "scheduling changed generated tokens");
    println!("streaming == gang tokens (bit-identical per request)");

    // Timed runs (fresh executors; cold shard upload included in both).
    let gang = run(Scheduling::Gang, 17);
    let streaming = run(Scheduling::Streaming, 17);

    let mut t = Table::new(&[
        "engine",
        "tok/s",
        "ttft mean (ms)",
        "ttft p95 (ms)",
        "lat mean (ms)",
        "lat p95 (ms)",
        "occupancy",
        "decode steps",
    ]);
    row(&mut t, "gang", &gang.metrics);
    row(&mut t, "streaming", &streaming.metrics);
    t.print();

    let gm = &gang.metrics;
    let sm = &streaming.metrics;
    // The acceptance bar: convoy elimination shows up as better mean
    // TTFT and better tail latency on the mixed trace, with weight
    // uploads flat (one layout's worth) for both fixed-plan runs.
    // Timing inequalities compare medians over three runs per mode.
    let mut gang_ttft = vec![gm.mean_ttft()];
    let mut gang_p95 = vec![gm.latency_p(95.0)];
    let mut str_ttft = vec![sm.mean_ttft()];
    let mut str_p95 = vec![sm.latency_p(95.0)];
    for _ in 0..2 {
        let g = run(Scheduling::Gang, 17);
        gang_ttft.push(g.metrics.mean_ttft());
        gang_p95.push(g.metrics.latency_p(95.0));
        let s = run(Scheduling::Streaming, 17);
        str_ttft.push(s.metrics.mean_ttft());
        str_p95.push(s.metrics.latency_p(95.0));
    }
    let (gang_ttft, gang_p95) = (median(gang_ttft), median(gang_p95));
    let (str_ttft, str_p95) = (median(str_ttft), median(str_p95));
    assert_eq!(
        sm.weight_uploads, gm.weight_uploads,
        "fixed-plan runs must upload exactly one layout's worth of shards"
    );
    println!(
        "mean TTFT {:.2}x better, p95 latency {:.2}x better (medians of 3), {} vs {} decode steps",
        gang_ttft / str_ttft,
        gang_p95 / str_p95,
        sm.decode_steps,
        gm.decode_steps,
    );

    // ---- Chunked prefill on the long-prompt/short-decode trace.
    let gang_long = run_long(Scheduling::Gang, 0, 23);
    let unchunked = run_long(Scheduling::Streaming, 0, 23);
    let chunked = run_long(Scheduling::Streaming, PREFILL_CHUNK, 23);
    assert_eq!(
        key(&gang_long),
        key(&unchunked),
        "unchunked streaming changed tokens on the long-prompt trace"
    );
    assert_eq!(
        key(&gang_long),
        key(&chunked),
        "chunked prefill changed generated tokens"
    );
    println!(
        "\nchunked prefill ({PREFILL_CHUNK}-token chunks, 64-token prompts): tokens bit-identical"
    );
    let mut t2 = Table::new(&[
        "streaming",
        "tok/s",
        "tpot mean (ms)",
        "tpot p95 (ms)",
        "ttft p95 (ms)",
        "lat p95 (ms)",
        "prefill chunks",
    ]);
    let long_row = |t: &mut Table, name: &str, m: &Metrics| {
        t.row(&[
            name.into(),
            format!("{:.1}", m.throughput()),
            format!("{:.2}", m.mean_tpot() * 1e3),
            format!("{:.2}", m.tpot_p(95.0) * 1e3),
            format!("{:.1}", m.ttft_p(95.0) * 1e3),
            format!("{:.1}", m.latency_p(95.0) * 1e3),
            format!("{}", m.prefill_chunks),
        ]);
    };
    long_row(&mut t2, "unchunked", &unchunked.metrics);
    long_row(&mut t2, &format!("chunk={PREFILL_CHUNK}"), &chunked.metrics);
    t2.print();

    let um = &unchunked.metrics;
    let cm = &chunked.metrics;
    // The acceptance bar: short-decode peers escape between chunks
    // instead of stalling behind a whole 64-token prefill, so their
    // tail time-per-output-token improves. Compared as medians over
    // three runs per mode, like the gang-vs-streaming asserts above.
    let mut un_p95 = vec![um.tpot_p(95.0)];
    let mut ch_p95 = vec![cm.tpot_p(95.0)];
    for _ in 0..2 {
        un_p95.push(run_long(Scheduling::Streaming, 0, 23).metrics.tpot_p(95.0));
        ch_p95.push(
            run_long(Scheduling::Streaming, PREFILL_CHUNK, 23).metrics.tpot_p(95.0),
        );
    }
    let (un_p95, ch_p95) = (median(un_p95), median(ch_p95));
    assert!(
        cm.prefill_chunks > cm.batches_prefilled,
        "prompts were not actually split into chunks"
    );
    println!(
        "peer p95 TPOT {:.2}x better (median of 3), mean TPOT {:.2}x, {} chunks over {} prefills",
        un_p95 / ch_p95,
        um.mean_tpot() / cm.mean_tpot().max(1e-12),
        cm.prefill_chunks,
        cm.batches_prefilled,
    );

    // ---- Recorder overhead on the mixed short/long streaming trace:
    // tracing must not perturb generation, and the per-hook cost
    // (one branch when disabled, event construction when enabled) must
    // stay under 5% of wall time.
    let run_traced = |enabled: bool, seed: u64| -> ServeReport {
        let m = meta();
        let weights = hap::model::WeightStore::synthetic(&m, 42);
        let mut exec = ModelExecutor::host(weights);
        let config = ServeConfig::tp(4);
        let recorder = if enabled { Recorder::new() } else { Recorder::disabled() };
        serve_with_recorder(&mut exec, &config, Scheduling::Streaming, trace(&m, seed), recorder)
            .unwrap()
    };
    let plain = run_traced(false, 29);
    let traced = run_traced(true, 29);
    assert_eq!(key(&plain), key(&traced), "recording changed generated tokens");
    assert!(!traced.trace.is_empty() && plain.trace.is_empty());
    let mut off_wall = vec![plain.metrics.wall_time];
    let mut on_wall = vec![traced.metrics.wall_time];
    for _ in 0..4 {
        off_wall.push(run_traced(false, 29).metrics.wall_time);
        on_wall.push(run_traced(true, 29).metrics.wall_time);
    }
    let (off_wall, on_wall) = (median(off_wall), median(on_wall));
    let overhead = on_wall / off_wall.max(1e-12) - 1.0;
    println!(
        "\nrecorder overhead: {:.2}% (enabled {on_wall:.4}s vs disabled {off_wall:.4}s, \
         medians of 5; {} events recorded)",
        overhead * 100.0,
        traced.trace.len(),
    );

    let summary = Json::obj(vec![
        ("bench", "serving_api".into()),
        ("profile", "release".into()),
        (
            "trace",
            Json::obj(vec![
                ("requests", REQUESTS.into()),
                ("short_gen", SHORT_GEN.into()),
                ("long_gen", LONG_GEN.into()),
                ("batch_slots", meta().batch.into()),
            ]),
        ),
        ("gang", metrics_json(gm)),
        ("streaming", metrics_json(sm)),
        (
            // Ratios from the same median-of-3 samples the acceptance
            // asserts use, so the artifact's verdict is self-consistent
            // (the per-engine blocks above are single-run snapshots).
            "improvement",
            Json::obj(vec![
                ("ttft_mean_median3", (gang_ttft / str_ttft.max(1e-12)).into()),
                ("latency_p95_median3", (gang_p95 / str_p95.max(1e-12)).into()),
                ("throughput_run1", (sm.throughput() / gm.throughput().max(1e-12)).into()),
            ]),
        ),
        (
            "chunked_prefill",
            Json::obj(vec![
                (
                    "trace",
                    Json::obj(vec![
                        ("requests", LONG_PROMPT_REQUESTS.into()),
                        ("prompt_tokens", long_prompt_meta().prefill_len.into()),
                        ("prefill_chunk", PREFILL_CHUNK.into()),
                    ]),
                ),
                (
                    "unchunked",
                    Json::obj(vec![
                        ("tpot_mean_s", um.mean_tpot().into()),
                        ("tpot_p95_s", um.tpot_p(95.0).into()),
                        ("tpot_p95_median3_s", un_p95.into()),
                        ("ttft_p95_s", um.ttft_p(95.0).into()),
                        ("latency_p95_s", um.latency_p(95.0).into()),
                        ("prefill_chunks", um.prefill_chunks.into()),
                    ]),
                ),
                (
                    "chunked",
                    Json::obj(vec![
                        ("tpot_mean_s", cm.mean_tpot().into()),
                        ("tpot_p95_s", cm.tpot_p(95.0).into()),
                        ("tpot_p95_median3_s", ch_p95.into()),
                        ("ttft_p95_s", cm.ttft_p(95.0).into()),
                        ("latency_p95_s", cm.latency_p(95.0).into()),
                        ("prefill_chunks", cm.prefill_chunks.into()),
                    ]),
                ),
                (
                    "improvement",
                    Json::obj(vec![
                        ("tpot_p95_median3", (un_p95 / ch_p95.max(1e-12)).into()),
                        (
                            "tpot_mean_run1",
                            (um.mean_tpot() / cm.mean_tpot().max(1e-12)).into(),
                        ),
                    ]),
                ),
            ]),
        ),
        (
            "recorder_overhead",
            Json::obj(vec![
                ("disabled_wall_median5_s", off_wall.into()),
                ("enabled_wall_median5_s", on_wall.into()),
                ("overhead_frac", overhead.into()),
                ("trace_events", traced.trace.len().into()),
            ]),
        ),
    ]);
    write_results("serving_api", &summary);
    let root_path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_serving_api.json");
    if let Err(e) = std::fs::write(&root_path, summary.to_string_pretty()) {
        eprintln!("could not write {}: {e}", root_path.display());
    } else {
        println!("wrote {}", root_path.display());
    }

    // Wall-clock acceptance bars LAST, after the artifacts are on
    // disk: a perf inversion on a noisy shared runner still leaves a
    // complete, well-formed BENCH_serving_api.json for inspection (and
    // for CI's artifact assertion) while the nonzero exit flags the
    // regression. All three are medians of three runs per mode.
    assert!(
        str_ttft < gang_ttft,
        "streaming median mean-TTFT {str_ttft:.4}s not better than gang {gang_ttft:.4}s"
    );
    assert!(
        str_p95 < gang_p95,
        "streaming median p95 latency {str_p95:.4}s not better than gang {gang_p95:.4}s"
    );
    assert!(
        ch_p95 < un_p95,
        "chunked prefill median p95 TPOT {ch_p95:.5}s not better than unchunked {un_p95:.5}s"
    );
    assert!(
        overhead < 0.05,
        "recorder overhead {:.2}% exceeds the 5% budget (medians of 5)",
        overhead * 100.0
    );
    println!("serving_api bench OK");
    Ok(())
}
