//! §Adaptive serving replay bench — the headline comparison for the
//! online adaptation loop: adaptive re-planning vs static TP vs the
//! best a-priori single plan vs the free-switch oracle, replayed over
//! deterministic traffic traces on the cluster simulator (no PJRT
//! artifacts needed). Overwrites BENCH_adaptive_serving.json at the
//! repo root with release-profile numbers and enforces the acceptance
//! bars (beats static TP; within 10% of oracle; >90% plan-cache hits).

use hap::adapt::replay::{self, ReplayComparison, WorkloadTrace};
use hap::adapt::ControllerConfig;
use hap::benchkit::{banner, write_results, Table};
use hap::config::{MoEModelConfig, NodeConfig};
use hap::planner::HapPlanner;
use hap::util::json::Json;

fn report_row(t: &mut Table, cmp: &ReplayComparison) {
    for r in cmp.policies() {
        let mut cells = vec![cmp.trace.clone()];
        cells.extend(cmp.row_cells(r));
        t.row(&cells);
    }
}

fn main() -> anyhow::Result<()> {
    banner("adaptive_serving", "trace-driven replay: adaptive vs static vs oracle");
    let model = MoEModelConfig::mixtral_8x7b();
    let node = NodeConfig::a6000x(4);
    let planner = HapPlanner::new(&model, &node);
    let config = ControllerConfig::default();

    let phase_shift = WorkloadTrace::phase_shift(80, 16, 17);
    let diurnal = WorkloadTrace::diurnal(120, 30, 32, 17);
    let ramp = WorkloadTrace::ramp(120, 16, 17);

    let mut t =
        Table::new(&["trace", "policy", "total (s)", "switches", "switch (s)", "vs adaptive"]);
    let ps = replay::compare(&planner, &phase_shift, &config, 32)?;
    report_row(&mut t, &ps);
    let di = replay::compare(&planner, &diurnal, &config, 32)?;
    report_row(&mut t, &di);
    let ra = replay::compare(&planner, &ramp, &config, 32)?;
    report_row(&mut t, &ra);
    t.print();

    println!("phase-shift: {}", ps.summary_line());

    let summary = Json::obj(vec![
        ("bench", "adaptive_serving".into()),
        ("profile", "release".into()),
        ("model", model.name.as_str().into()),
        ("node", node.label().into()),
        ("phase_shift", ps.to_json()),
        ("diurnal", di.to_json()),
        ("ramp", ra.to_json()),
    ]);
    write_results("adaptive_serving", &summary);
    let root_path =
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_adaptive_serving.json");
    if let Err(e) = std::fs::write(&root_path, summary.to_string_pretty()) {
        eprintln!("could not write {}: {e}", root_path.display());
    } else {
        println!("wrote {}", root_path.display());
    }

    // Acceptance bars (ISSUE 2), enforced under the release profile.
    assert!(
        ps.adaptive.total_s < ps.static_tp.total_s,
        "adaptive {:.3}s did not beat static TP {:.3}s",
        ps.adaptive.total_s,
        ps.static_tp.total_s
    );
    assert!(
        ps.adaptive.total_s <= ps.static_first.total_s * 1.0005,
        "adaptive lost to the static first-phase plan"
    );
    assert!(
        ps.vs_oracle() <= 1.10,
        "adaptive is {:.1}% over the oracle (>10%)",
        (ps.vs_oracle() - 1.0) * 100.0
    );
    assert!(
        ps.adaptive.cache_hit_rate > 0.90,
        "plan cache hit rate {:.1}% <= 90%",
        ps.adaptive.cache_hit_rate * 100.0
    );
    println!("adaptive_serving OK");
    Ok(())
}
