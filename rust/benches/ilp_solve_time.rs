//! Paper claim (§III-C): "For typical limited-scale deployment
//! scenarios (e.g., single-machine 8-GPU configurations), the
//! optimization completes consistently within one second."
//!
//! Measures the full plan() call (search-space build + cost tables +
//! ILP formulate + solve) and the bare ILP solve across models/nodes.

mod common;

use hap::benchkit::{banner, bench, write_results, Table};
use hap::config::{MoEModelConfig, NodeConfig, Scenario};
use hap::planner::HapPlanner;
use hap::util::json::Json;

fn main() -> anyhow::Result<()> {
    banner("ilp", "ILP + full-plan solve times (paper: < 1 s)");
    let mut t = Table::new(&["model", "node", "scenario", "full plan (ms)", "ILP only (ms)", "nodes"]);
    let mut json = Vec::new();
    let mut worst = 0.0f64;
    for model in MoEModelConfig::paper_models() {
        for node in [NodeConfig::a6000x(4), NodeConfig::a100x(8)] {
            let planner = HapPlanner::new(&model, &node);
            for sc in [Scenario::long_constrained(), Scenario::long_extended()] {
                let space = planner.search_space(&sc);
                let tables = planner.cost_tables(&space, &sc);
                let (problem, _) = planner.formulate(&space, &tables, &sc);
                let ilp_t = bench("ilp", 2, 0.15, || {
                    let out = hap::ilp::solve(&problem);
                    std::hint::black_box(out.optimal().map(|(_, o)| o));
                });
                let plan_t = bench("plan", 1, 0.3, || {
                    let p = planner.plan(&sc, sc.generate).unwrap();
                    std::hint::black_box(p.predicted_total);
                });
                let nodes_explored = match hap::ilp::solve(&problem) {
                    hap::ilp::Outcome::Optimal { nodes_explored, .. } => nodes_explored,
                    _ => 0,
                };
                worst = worst.max(plan_t.median);
                t.row(&[
                    model.name.clone(),
                    node.label(),
                    sc.name.clone(),
                    format!("{:.1}", plan_t.median * 1e3),
                    format!("{:.2}", ilp_t.median * 1e3),
                    format!("{nodes_explored}"),
                ]);
                json.push(Json::obj(vec![
                    ("model", model.name.as_str().into()),
                    ("node", node.label().as_str().into()),
                    ("scenario", sc.name.as_str().into()),
                    ("plan_ms", (plan_t.median * 1e3).into()),
                    ("ilp_ms", (ilp_t.median * 1e3).into()),
                ]));
            }
        }
    }
    t.print();
    println!("\nworst full-plan median: {:.1} ms (paper budget: 1000 ms)", worst * 1e3);
    assert!(worst < 1.0, "plan exceeded the paper's 1 s budget");
    write_results("ilp_solve_time", &Json::obj(vec![("rows", Json::Arr(json))]));
    println!("ilp_solve_time OK");
    Ok(())
}
