//! §Paged-KV benchmark — BENCH_paged_kv.json at the repo root.
//!
//! A shared-system-prompt trace (every request opens with the same
//! prompt) served twice at **equal KV memory**:
//!
//!  - **padded baseline**: the host-demo grid (4 slots × `max_len`
//!    rows = 192 cached tokens, allocated up front per slot);
//!  - **paged**: 8 slots over a 24-block pool of 8-token blocks — the
//!    same 192-token capacity — with copy-on-write prefix sharing, so
//!    admission is bounded by *reserved blocks*, not slot rows.
//!
//! Reported: admitted concurrency (peak live slots), peak KV bytes
//! actually in use, mean TTFT, and the prefix-cache hit counters. The
//! paged run must admit strictly more concurrent requests and touch
//! fewer peak KV bytes, at per-request tokens bit-identical to the
//! padded baseline.

use hap::benchkit::{banner, bench, write_results, Table};
use hap::model::{KvLayout, PagedKvStats, WeightStore};
use hap::runtime::TinyModelMeta;
use hap::serving::{Engine, Request, ServeConfig, ServeReport};
use hap::util::json::Json;
use hap::util::rng::Rng;

/// Every request carries the same system prompt (two tokens short of
/// `prefill_len`, so left-padding is exercised) and a small per-request
/// generation budget.
fn shared_prompt_workload(m: &TinyModelMeta, n: usize) -> Vec<Request> {
    let mut rng = Rng::new(11);
    let prompt: Vec<i32> =
        (0..m.prefill_len - 2).map(|_| rng.below(m.vocab) as i32).collect();
    (0..n as u64).map(|id| Request::new(id, prompt.clone(), 4)).collect()
}

fn sorted_tokens(report: &ServeReport) -> Vec<(u64, Vec<i32>)> {
    let mut t: Vec<(u64, Vec<i32>)> =
        report.responses.iter().map(|r| (r.id, r.tokens.clone())).collect();
    t.sort();
    t
}

struct RunStats {
    iters: usize,
    /// Peak live slots over the run — the admitted concurrency.
    max_running: usize,
    /// Peak pool blocks in use (paged runs only).
    peak_blocks: usize,
    kv: Option<PagedKvStats>,
    report: ServeReport,
}

/// Serve the shared-prompt workload on a tp=4 streaming engine,
/// tracking peak concurrency and peak block occupancy per iteration.
fn serve(m: &TinyModelMeta, kv: KvLayout, n: usize) -> anyhow::Result<RunStats> {
    let mut config = ServeConfig::tp(4);
    config.kv = kv;
    let mut engine = Engine::builder(config).build_host(WeightStore::synthetic(m, 42));
    for req in shared_prompt_workload(m, n) {
        engine.submit(req)?;
    }
    let mut iters = 0usize;
    let (mut max_running, mut peak_blocks) = (0usize, 0usize);
    loop {
        let out = engine.step()?;
        iters += 1;
        max_running = max_running.max(out.running);
        if let Some(stats) = engine.executor().paged_stats() {
            peak_blocks = peak_blocks.max(stats.blocks_in_use);
        }
        if out.idle() {
            break;
        }
    }
    let kv_stats = engine.executor().paged_stats();
    Ok(RunStats { iters, max_running, peak_blocks, kv: kv_stats, report: engine.shutdown()? })
}

fn main() -> anyhow::Result<()> {
    banner("paged_kv", "paged vs padded KV at equal memory: concurrency, TTFT, peak bytes");
    let n = 24usize;

    // Padded baseline: host-demo shape, 4 slots, each owning a full
    // max_len KV row — 4 × 48 = 192 cached-token capacity.
    let padded_meta = TinyModelMeta::host_demo();
    // Paged: twice the slots, but the *same* 192-token KV capacity
    // carved into 24 blocks of 8 tokens; admission reserves blocks.
    let mut paged_meta = TinyModelMeta::host_demo();
    paged_meta.batch = 8;
    const BLOCK_SIZE: usize = 8;
    const NUM_BLOCKS: usize = 24;
    let layout = KvLayout::Paged { block_size: BLOCK_SIZE, num_blocks: NUM_BLOCKS };
    assert_eq!(
        NUM_BLOCKS * BLOCK_SIZE,
        padded_meta.batch * padded_meta.max_len,
        "the comparison holds KV token capacity equal"
    );
    // Logical bytes per cached token (K + V, f32, all layers).
    let tok_bytes = padded_meta.layers * padded_meta.kv_heads * padded_meta.head_dim * 2 * 4;

    // --- Correctness gate: identical per-request tokens, more
    // concurrency, fewer peak KV bytes.
    let padded = serve(&padded_meta, KvLayout::Padded, n)?;
    let paged = serve(&paged_meta, layout, n)?;
    assert_eq!(padded.report.metrics.requests_completed, n);
    assert_eq!(paged.report.metrics.requests_completed, n, "paged run lost requests");
    assert_eq!(
        sorted_tokens(&paged.report),
        sorted_tokens(&padded.report),
        "paged tokens diverged from the padded baseline"
    );
    assert!(
        paged.max_running > padded.max_running,
        "paged must admit more concurrent requests at equal KV memory \
         (paged {} vs padded {})",
        paged.max_running,
        padded.max_running
    );
    let padded_peak_bytes = padded_meta.batch * padded_meta.max_len * tok_bytes;
    let paged_peak_bytes = paged.peak_blocks * BLOCK_SIZE * tok_bytes;
    assert!(
        paged_peak_bytes < padded_peak_bytes,
        "prefix sharing must keep peak block bytes under the padded allocation \
         ({paged_peak_bytes} vs {padded_peak_bytes})"
    );
    let kv = paged.kv.expect("paged run exposes pool stats");
    assert!(kv.prefix_hits > 0, "shared prompts must hit the prefix trie");
    println!(
        "paged: {}/{} slots live at peak (padded {}), {} prefix hits sharing {} tokens, \
         {} COW copies, peak {} of {} blocks",
        paged.max_running,
        paged_meta.batch,
        padded.max_running,
        kv.prefix_hits,
        kv.prefix_shared_tokens,
        kv.cow_copies,
        paged.peak_blocks,
        NUM_BLOCKS
    );

    // --- Wall time per layout.
    let t_padded = bench("paged-kv-padded-4slot", 1, 1.0, || {
        std::hint::black_box(serve(&padded_meta, KvLayout::Padded, n).unwrap());
    });
    let t_paged = bench("paged-kv-paged-8slot", 1, 1.0, || {
        std::hint::black_box(serve(&paged_meta, layout, n).unwrap());
    });

    let mut table = Table::new(&[
        "layout",
        "slots",
        "peak live",
        "peak KV bytes",
        "mean TTFT",
        "sched iters",
        "median",
    ]);
    for (name, meta, run, peak_bytes, t) in [
        ("padded", &padded_meta, &padded, padded_peak_bytes, &t_padded),
        ("paged 24x8", &paged_meta, &paged, paged_peak_bytes, &t_paged),
    ] {
        table.row(&[
            name.into(),
            format!("{}", meta.batch),
            format!("{}", run.max_running),
            format!("{peak_bytes}"),
            hap::util::fmt_secs(run.report.metrics.mean_ttft()),
            format!("{}", run.iters),
            hap::util::fmt_secs(t.median),
        ]);
    }
    table.print();

    let run_json = |run: &RunStats, peak_bytes: usize, slots: usize, median: f64| {
        Json::obj(vec![
            ("slots", slots.into()),
            ("max_running", run.max_running.into()),
            ("peak_kv_bytes", peak_bytes.into()),
            ("mean_ttft_s", run.report.metrics.mean_ttft().into()),
            ("sched_iters", run.iters.into()),
            ("median_s", median.into()),
        ])
    };
    let summary = Json::obj(vec![
        ("bench", "paged_kv".into()),
        ("profile", "release".into()),
        ("requests", n.into()),
        ("kv_token_capacity", (NUM_BLOCKS * BLOCK_SIZE).into()),
        ("padded", run_json(&padded, padded_peak_bytes, padded_meta.batch, t_padded.median)),
        (
            "paged",
            Json::obj(vec![
                ("slots", paged_meta.batch.into()),
                ("block_size", BLOCK_SIZE.into()),
                ("num_blocks", NUM_BLOCKS.into()),
                ("max_running", paged.max_running.into()),
                ("peak_blocks", paged.peak_blocks.into()),
                ("peak_kv_bytes", paged_peak_bytes.into()),
                ("mean_ttft_s", paged.report.metrics.mean_ttft().into()),
                ("sched_iters", paged.iters.into()),
                ("median_s", t_paged.median.into()),
                ("prefix_hits", (kv.prefix_hits as usize).into()),
                ("prefix_shared_tokens", (kv.prefix_shared_tokens as usize).into()),
                ("cow_copies", (kv.cow_copies as usize).into()),
            ]),
        ),
        ("tokens_bit_identical", true.into()),
    ]);
    write_results("paged_kv", &summary);
    let root_path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("BENCH_paged_kv.json");
    if let Err(e) = std::fs::write(&root_path, summary.to_string_pretty()) {
        eprintln!("could not write {}: {e}", root_path.display());
    } else {
        println!("wrote {}", root_path.display());
    }
    println!("paged_kv bench OK");
    Ok(())
}
