//! Paper Fig 9: long-context (4096) / extended-generation (2048) —
//! dual-phase workload. Phase-specific strategies (EP-ish prefill →
//! TP decode with the dynamic transition) win modestly (paper ≤1.13×).

mod common;

use common::{report, speedup_row, BATCHES};
use hap::config::{MoEModelConfig, NodeConfig, Scenario};
use hap::planner::HapPlanner;

fn main() -> anyhow::Result<()> {
    for node in [NodeConfig::a6000x(4), NodeConfig::a100x(4)] {
        let mut rows = Vec::new();
        for model in MoEModelConfig::paper_models() {
            for b in BATCHES {
                let sc = Scenario::long_extended().with_batch(b);
                rows.push(speedup_row(&model, &node, &sc, 1)?);
            }
        }
        report(
            &format!("fig9_{}", node.label()),
            &format!("long ctx (4096) / extended gen (2048) on {}", node.label()),
            &rows,
        );
        for r in &rows {
            assert!(r.speedup > 0.95, "HAP lost: {} {}", r.model, r.speedup);
        }
    }
    // Check the phase-specific structure exists for at least one model
    // on the PCIe node: prefill strategy != decode strategy.
    let node = NodeConfig::a6000x(4);
    let mut any_transition = false;
    for model in MoEModelConfig::paper_models() {
        let planner = HapPlanner::new(&model, &node);
        let plan = planner.plan(&Scenario::long_extended(), 2048)?;
        println!("{}: {}", model.name, plan.signature());
        any_transition |= plan.has_transition() || plan.attn.dp > 1;
    }
    assert!(any_transition, "expected phase-specific or low-comm structure somewhere");
    println!("fig9 OK");
    Ok(())
}
