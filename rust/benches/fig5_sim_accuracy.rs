//! Paper Fig 5: prediction accuracy of the computational and
//! communication simulation models (η and ρ random-forest regressors)
//! against held-out measured operator latencies.
//!
//! Shape to hold: communication error < 5%, computational error < 10%.

mod common;

use hap::benchkit::{banner, write_results, Table};
use hap::config::GpuSpec;
use hap::sim::latency::heldout_errors;
use hap::sim::LatencyModel;
use hap::util::json::Json;
use hap::util::stats;

fn main() {
    banner("fig5", "simulation-model prediction error (held-out)");
    let mut t = Table::new(&["platform", "compute mean err", "compute p95", "comm mean err", "comm p95"]);
    let mut json = Vec::new();
    let mut worst_comp = 0.0f64;
    let mut worst_comm = 0.0f64;
    for gpu in [GpuSpec::a6000(), GpuSpec::a100(), GpuSpec::v100()] {
        let lm = LatencyModel::train(&gpu, 0x4A9);
        let (comp, comm) = heldout_errors(&lm, &gpu, 400);
        let cm = stats::mean(&comp);
        let cq = stats::percentile(&comp, 95.0);
        let mm = stats::mean(&comm);
        let mq = stats::percentile(&comm, 95.0);
        worst_comp = worst_comp.max(cm);
        worst_comm = worst_comm.max(mm);
        t.row(&[
            gpu.name.clone(),
            format!("{:.1}%", cm * 100.0),
            format!("{:.1}%", cq * 100.0),
            format!("{:.1}%", mm * 100.0),
            format!("{:.1}%", mq * 100.0),
        ]);
        json.push(Json::obj(vec![
            ("platform", gpu.name.as_str().into()),
            ("compute_mean_err", cm.into()),
            ("comm_mean_err", mm.into()),
        ]));
    }
    t.print();
    println!("\npaper targets: compute <10%, comm <5%");
    assert!(worst_comp < 0.10, "compute error {worst_comp:.3} exceeds 10%");
    assert!(worst_comm < 0.05, "comm error {worst_comm:.3} exceeds 5%");
    write_results("fig5", &Json::obj(vec![("rows", Json::Arr(json))]));
    println!("fig5 OK");
}
